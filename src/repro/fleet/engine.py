"""Fleet engine: N concurrent training jobs on ONE shared substrate.

Every run builds exactly one :class:`~repro.sim.clock.SimClock`, one
:class:`~repro.sim.topology.Topology` and one fault
:class:`~repro.sim.clock.EventQueue`; N modelled training jobs (the soak
engine's cost model, per job) advance on that single timeline:

* the :class:`~repro.fleet.scheduler.FleetScheduler` gang-schedules jobs,
  queues the ones that don't fit, and arbitrates every replacement claim
  through the topology's lease ledger — two recovering jobs can never be
  handed the same spare;
* a low-priority job can be **preempted**: elastically shrunk by one machine
  to unblock a high-priority job's recovery when the shared pool is dry
  (the donor pays a reshard — rollback to its last durable checkpoint and a
  restore through the store);
* checkpoint saves and store restores are **flows on one shared NAS**
  (:class:`~repro.core.tce.store.SharedBandwidth`, processor sharing): one
  job's restore waterfall visibly slows another job's async save, and a
  save that hasn't drained when a crash lands is torn (not durable);
* correlated faults carry their failure-domain tag, so a rack/switch outage
  hits every co-located job in the same event (reported per ``(t, domain)``
  group) and replacements avoid the failed domain.

The run is fully seeded and emits a deterministic JSON-able report with
per-job recovery/goodput sections and fleet-level utilization.
"""
from __future__ import annotations

import heapq
import math
import time
from dataclasses import dataclass, replace
from typing import Dict, List, Optional, Set, Tuple

import numpy as np

from repro.core.tce.store import NAS_BW_PER_RANK, SharedBandwidth
from repro.recovery import (RECOVER_IN_PLACE, REGROW, SRC_CACHE, SRC_STORE,
                            ClusterState, CostModel, Incident,
                            RecoveryExecutor, RecoveryPlanner, fill_slots)
from repro.recovery.executor import WAITING as PLAN_WAITING
from repro.sim.clock import EventQueue, SimClock
from repro.sim.faults import (FaultEvent, FaultInjector, cascade_events,
                              domain_outage_schedule, get_mix,
                              group_domain_incidents, merge_schedules,
                              push_schedule)
from repro.sim.soak import DAY_S, NODE_ATTRIBUTABLE, SoakPolicy
from repro.sim.topology import NodeState, Topology
from repro.tee_stream import (CrossJobCorrelator, FleetStreamTEE,
                              StreamObservation)

from .scheduler import FleetScheduler, JobSpec

_EPS = 1e-6

# job lifecycle states; DETECT/RESCHEDULE/RESTORE/WARMUP are the phases of
# one open recovery transaction
PENDING, RUNNING, STALLED = "pending", "running", "stalled"
DETECT, RESCHEDULE, RESTORE, WARMUP = ("detect", "reschedule", "restore",
                                       "warmup")
WAITING, DONE = "waiting", "done"
_RECOVERY = frozenset({DETECT, RESCHEDULE, RESTORE, WARMUP, WAITING})

# states with no timed deadline: excluded from the wakeup heap (RUNNING jobs
# wake on progress markers instead; WAITING jobs wake on repairs)
_UNTIMED = (PENDING, RUNNING, WAITING, DONE)

# process-wide overrides consumed by :func:`run_fleet` — they let the CLI
# (``--profile``) and the equivalence suite flip behaviour underneath preset
# functions that build their own FleetConfig
_FORCE_LEGACY = False       # run every fleet under the legacy dispatcher
_PROFILE = False            # attach a ``measured`` phase-time breakdown


def set_force_legacy(flag: bool) -> None:
    """Force ``legacy_dispatch=True`` on every subsequent :func:`run_fleet`
    (the equivalence suite's hook under preset functions)."""
    global _FORCE_LEGACY
    _FORCE_LEGACY = bool(flag)


def set_profile(flag: bool) -> None:
    """Attach a ``measured`` section (wall time, tick count, per-phase
    breakdown) to every subsequent :func:`run_fleet` report. The simulation
    itself is unchanged — reports stay byte-identical sans ``measured``."""
    global _PROFILE
    _PROFILE = bool(flag)


@dataclass(frozen=True)
class FleetConfig:
    """One fleet run: a shared cluster, N job specs, a fault environment."""
    jobs: Tuple[JobSpec, ...]
    n_nodes: int = 16
    n_spares: int = 4
    nodes_per_rack: int = 8
    racks_per_switch: int = 4
    repair_hours: float = 4.0
    # one shared NAS uplink (paper §IV-C per-rank bandwidth x a few ranks):
    # an 8 GB checkpoint drains in ~28 s solo, ~56 s with one contender
    nas_bw_total: float = 4 * NAS_BW_PER_RANK
    preemption: bool = True
    # stochastic fault environment (0 disables each source)
    mtbf_node_days: float = 0.0
    straggler_frac: float = 0.15
    p_cascade: float = 0.0
    cascade_window_s: float = 600.0
    rack_mtbf_days: float = 0.0
    horizon_days: float = 30.0
    scripted: Tuple[FaultEvent, ...] = ()        # deterministic extra events
    planner_policy: str = "transom"              # RecoveryPlanner policy
    fault_mix: str = "table1"                    # category mix (faults.MIXES)
    # streaming TEE (Eagle Eye): degradation faults are detected by scoring
    # the affected jobs' metric streams (vectorized, confidence-weighted,
    # cross-job correlated by failure domain) instead of firing instantly
    tee_stream: bool = False
    tee_correlation_s: float = 900.0             # domain correlation window
    # N-tier checkpoint hierarchy knobs (repro.recovery.tiers):
    # ``restore_prefetch`` speculatively streams the store checkpoint on the
    # shared NAS while a job is still rescheduling, so the restore leg only
    # pays the residual; ``tier_correlated`` models the peer-ring backup tier
    # sharing the rack failure domain — a rack outage takes the ring with it
    # and the recovery escalates straight to the durable store tiers
    restore_prefetch: bool = False
    tier_correlated: bool = False
    # background TieredStore demotions on the shared NAS: scripted
    # ``(t_s, nbytes)`` flows modelling capacity-driven step aging
    # (``TieredStore.demote_due``) contending with foreground saves/restores
    demotion_traffic: Tuple[Tuple[float, float], ...] = ()
    # A/B switch: run the poll-everything control loop that predates the
    # indexed dispatcher (scans every job on every wakeup). Reports are
    # byte-identical between the two paths (pinned in
    # tests/test_fleet_dispatch.py); only wall time differs.
    legacy_dispatch: bool = False
    seed: int = 0


class _Job:
    """Runtime state of one job (spec + progress + open-recovery fields).

    ``done`` (productive seconds banked) is array-backed: the value lives in
    the run's shared numpy vector at this job's ``idx``, so the indexed
    dispatcher can advance every running job's progress in one vectorized
    operation while per-job handlers keep reading/writing ``job.done`` as a
    plain float (same IEEE-double arithmetic either way).
    """

    def __init__(self, spec: JobSpec, idx: int, done_arr: np.ndarray):
        self.spec = spec
        self.idx = idx
        self._done_arr = done_arr
        self.pol: SoakPolicy = spec.policy
        self.state = PENDING
        self.until = math.inf            # end of the current timed phase
        self.need = spec.ideal_hours * 3600.0
        self.last_ckpt = 0.0             # durable checkpoint (productive s)
        self.next_ckpt = spec.ckpt_interval_s
        self.save_flow: Optional[Tuple[int, float]] = None   # (fid, snapshot)
        self.restore_flow: Optional[int] = None
        self.prefetch_flow: Optional[int] = None  # speculative store stream
        self.prefetch_done = False
        # open recovery transaction
        self.inplace = False
        self.escalate = False
        self.recovery_t0 = 0.0
        self.pending_replace = 0
        self.wait_start = 0.0
        self.wait_s_in_open = 0.0
        self.restore_src = SRC_CACHE
        self.victim_racks: List[str] = []
        # lifetime stats
        self.admitted_at = math.inf
        self.finished_at = math.inf
        self.final_nodes = 0
        self.lost_s = 0.0
        self.restart_times: List[float] = []
        self.downtime_s = 0.0
        self.restore_sources: Dict[str, int] = {}
        self.counts = dict(faults_hit=0, absorbed=0, domain_hits=0,
                           shrinks=0, regrows=0, donations_given=0,
                           donations_taken=0, waits=0, saves_started=0,
                           saves_durable=0, saves_torn=0, saves_skipped=0,
                           prefetch_started=0, prefetch_hits=0)
        self.wait_s = 0.0
        self._done_counted = False       # _FleetRun._n_done accounting
        # CostModel view of this job's policy for the shared planner
        self.cost_model = CostModel.from_soak_policy(self.pol)

    @property
    def done(self) -> float:
        return float(self._done_arr[self.idx])

    @done.setter
    def done(self, v: float) -> None:
        self._done_arr[self.idx] = v

    @property
    def active(self) -> bool:
        return self.state not in (PENDING, DONE)

    def rate(self, view) -> float:
        return len(view.assigned) / self.spec.n_nodes


class _FleetRun:
    def __init__(self, cfg: FleetConfig, seed: int):
        self.cfg = cfg
        self.seed = seed
        self.rng = np.random.default_rng(seed)
        self.clock = SimClock()
        self.topo = Topology(cfg.n_nodes, n_spares=cfg.n_spares,
                             repair_hours=cfg.repair_hours,
                             nodes_per_rack=cfg.nodes_per_rack,
                             racks_per_switch=cfg.racks_per_switch,
                             clock=self.clock, auto_assign=False)
        self.sched = FleetScheduler(self.topo)
        self.nas = SharedBandwidth(cfg.nas_bw_total)
        self.events = EventQueue(self.clock)
        self.jobs: Dict[str, _Job] = {}
        self.specs: Dict[str, JobSpec] = {}
        # vectorized per-job progress state (see _Job.done): one slot per
        # job in spec order == jobs-dict insertion order
        n = len(cfg.jobs)
        self._done_arr = np.zeros(n)
        self._rate_arr = np.zeros(n)
        self._running_arr = np.zeros(n, dtype=bool)
        self._marker_arr = np.full(n, math.inf)
        for idx, spec in enumerate(cfg.jobs):
            if spec.n_nodes > cfg.n_nodes:
                raise ValueError(f"{spec.name}: wants {spec.n_nodes} nodes, "
                                 f"fleet has {cfg.n_nodes}")
            self.specs[spec.name] = spec
            self.jobs[spec.name] = _Job(spec, idx, self._done_arr)
            if spec.submit_at_s > 0:
                self.events.push(spec.submit_at_s, ("submit", spec.name))
        self._by_idx: List[_Job] = list(self.jobs.values())
        # -- indexed-dispatch state (maintained in both modes; only the
        # indexed loop reads it) ---------------------------------------- #
        # per-job generation counters: every _touch bumps a job's gen, so
        # heap entries carrying an older gen are dropped lazily on pop
        self._gen = [0] * n
        self._until_heap: List[Tuple[float, int, int]] = []   # (until, i, g)
        self._pending_set: Set[int] = set(range(n))   # every job starts PENDING
        self._waiting_set: Set[int] = set()
        self._shrunk_set: Set[int] = set()
        self._zero_rate: Set[int] = set()
        self._n_done = 0
        self._newly_admitted: List[str] = []
        # NAS flow index: fid -> owning job (replaces the all-jobs scan in
        # _nas_completions); plus background demotion flows, which no job
        # owns
        self._flow_owner: Dict[int, _Job] = {}
        self._demote_fids: Set[int] = set()
        # arbiter next-completion cache, keyed on (rate-change epoch,
        # virtual time): valid until a flow starts/cancels/completes or the
        # arbiter's piecewise drain advances
        self._nas_cache_key: Optional[Tuple[int, float]] = None
        self._nas_cache_val: Optional[float] = None
        self._legacy = cfg.legacy_dispatch
        self._ticks = 0
        self._wall_s = 0.0
        self._prof: Optional[Dict[str, float]] = None
        for t_d, nbytes in cfg.demotion_traffic:
            self.events.push(float(t_d), ("demote", float(nbytes)))
        schedule: List[FaultEvent] = list(cfg.scripted)
        weights = (None if cfg.fault_mix == "table1"
                   else dict(get_mix(cfg.fault_mix).weights))
        if cfg.mtbf_node_days > 0:
            primary = FaultInjector(
                cfg.n_nodes, cfg.mtbf_node_days,
                horizon_days=cfg.horizon_days,
                straggler_frac=cfg.straggler_frac, seed=seed,
                weights=weights).schedule()
            if cfg.p_cascade > 0:
                primary = cascade_events(
                    primary, list(self.topo.nodes), p_cascade=cfg.p_cascade,
                    recovery_window_s=cfg.cascade_window_s, seed=seed + 1,
                    weights=weights)
            schedule = merge_schedules(schedule, primary)
        if cfg.rack_mtbf_days > 0:
            schedule = merge_schedules(schedule, domain_outage_schedule(
                self.topo, "rack", cfg.rack_mtbf_days, cfg.horizon_days,
                seed=seed + 2))
        self.n_injected = push_schedule(self.events, schedule)
        # ONE recovery brain across every job: claim-vs-preempt-vs-shrink-
        # vs-wait and regrow-on-repair are planned here, per-job costs
        # supplied per call; the engine below is mechanism only
        self.planner = RecoveryPlanner(cfg.planner_policy)
        self.counts = dict(idle_faults=0, job_faults=0, preemptions=0,
                           demotions_started=0, demotions_drained=0)
        # (t, domain) -> set of job names hit by that correlated event
        self.correlated: Dict[Tuple[float, str], Set[str]] = {}
        # streaming TEE service + cross-job correlator (Eagle Eye)
        self.tee: Optional[FleetStreamTEE] = None
        self.tee_correlator: Optional[CrossJobCorrelator] = None
        self.tee_incidents: List[dict] = []
        if cfg.tee_stream:
            self.tee = FleetStreamTEE(seed=seed)
            self.tee_correlator = CrossJobCorrelator(cfg.tee_correlation_s)

    # ------------------------------------------------------------------ #
    def _view(self, job: _Job):
        return self.sched.views[job.spec.name]

    def _detect_s(self, pol: SoakPolicy) -> float:
        if pol.weekend_frac > 0 and self.rng.random() < pol.weekend_frac:
            return pol.weekend_detect_s
        return float(self.rng.exponential(pol.detect_mean_s))

    def _next_repair(self) -> Optional[float]:
        # O(1): the array-backed topology caches its min repair deadline
        due = self.topo.next_repair_at()
        if due is None:
            return None
        return max(due, self.clock.seconds + 1.0)

    # -- indexed-dispatch bookkeeping ----------------------------------- #
    def _touch(self, job: _Job) -> None:
        """Refresh every index the dispatcher keeps for ``job``: the rate /
        running / marker vectors, the pending/waiting/shrunk/zero-rate dirty
        sets, the done counter, and (lazily, via a bumped generation) its
        wakeup-heap entry. Called by every handler that mutates a job's
        state, phase deadline, assignment or checkpoint marker. Inert under
        legacy dispatch — the poll loop rescans instead, keeping its cost
        profile honest for the A/B."""
        if self._legacy:
            return
        i = job.idx
        self._gen[i] += 1
        st = job.state
        view = self.sched.views.get(job.spec.name)
        r = len(view.assigned) / job.spec.n_nodes if view is not None else 0.0
        running = st == RUNNING
        self._rate_arr[i] = r
        self._running_arr[i] = running
        self._marker_arr[i] = min(job.next_ckpt, job.need)
        (self._pending_set.add if st == PENDING
         else self._pending_set.discard)(i)
        (self._waiting_set.add if st == WAITING
         else self._waiting_set.discard)(i)
        shrunk = (running and view is not None
                  and len(view.assigned) < job.spec.n_nodes)
        (self._shrunk_set.add if shrunk else self._shrunk_set.discard)(i)
        (self._zero_rate.add if running and r <= 0.0
         else self._zero_rate.discard)(i)
        if st == DONE and not job._done_counted:
            job._done_counted = True
            self._n_done += 1
        if job.until < math.inf and st not in _UNTIMED:
            heapq.heappush(self._until_heap, (job.until, i, self._gen[i]))

    def _nas_start(self, t: float, nbytes: float, label: str,
                   job: _Job) -> int:
        fid = self.nas.start(t, nbytes, label)
        self._flow_owner[fid] = job
        return fid

    def _nas_cancel(self, fid: int) -> None:
        self.nas.cancel(fid)
        self._flow_owner.pop(fid, None)

    def _nas_next(self) -> Optional[float]:
        """Cached ``SharedBandwidth.next_completion``: the prediction is
        recomputed only when the arbiter's rate-change epoch (a flow
        started/cancelled/completed) or its piecewise virtual time moved —
        otherwise the flow set and shares are unchanged and the cached
        completion time is still exact."""
        key = (self.nas.epoch, self.nas.virtual_time)
        if key != self._nas_cache_key:
            self._nas_cache_key = key
            self._nas_cache_val = self.nas.next_completion()
        return self._nas_cache_val

    def _activate(self, job: _Job, t: float) -> None:
        if job.state == PENDING:
            job.state = RUNNING
            job.admitted_at = t
            job.next_ckpt = job.spec.ckpt_interval_s
            self._touch(job)

    def _try_admit(self, t: float) -> None:
        for spec in self.sched.try_admit():
            self._activate(self.jobs[spec.name], t)
        # jobs admitted by a mid-dispatch scheduler.submit() call (submit
        # events) activate here, on the same _process pass as before
        while self._newly_admitted:
            self._activate(self.jobs[self._newly_admitted.pop(0)], t)

    # -- recovery transaction ------------------------------------------- #
    def _open_recovery(self, job: _Job, t: float, victims: List[str],
                       inplace: bool,
                       detect_s: Optional[float] = None) -> None:
        """Open one recovery transaction. ``detect_s`` overrides the drawn
        detection time — streaming-TEE incidents already paid detection on
        the metric stream, so they open with ``detect_s=0.0``."""
        if job.save_flow is not None:
            # the crash tears the in-flight save: it never becomes durable
            self._nas_cancel(job.save_flow[0])
            job.save_flow = None
            job.counts["saves_torn"] += 1
        job.state = DETECT
        job.inplace = inplace
        job.escalate = False
        job.recovery_t0 = t
        job.pending_replace = 0
        job.wait_s_in_open = 0.0
        job.victim_racks = []
        if detect_s is None:
            detect_s = self._detect_s(job.pol)
        job.until = t + detect_s + job.pol.error_check_s
        self._evict_and_note(job, t, victims)

    def _evict_and_note(self, job: _Job, t: float,
                        victims: List[str]) -> None:
        view = self._view(job)
        for v in victims:
            job.victim_racks.append(self.topo.domain_of(v))
            view.evict(v, t)
            job.pending_replace += 1
        self._touch(job)

    def _avoid_domains(self, job: _Job) -> Set[str]:
        # 2+ victims in one rack point at a correlated root cause: keep
        # replacements out of that failure domain (domain-tagged events
        # already recorded each victim's rack here too)
        hits: Dict[str, int] = {}
        for r in job.victim_racks:
            hits[r] = hits.get(r, 0) + 1
        return {r for r, c in hits.items() if c >= 2}

    def _find_donor(self, spec) -> Optional[str]:
        """Mechanism: the scheduler names the lowest-priority shrinkable job
        among those not currently mid-recovery."""
        if not self.cfg.preemption:
            return None
        donatable = {n for n, j in self.jobs.items()
                     if j.state in (RUNNING, STALLED)}
        return self.sched.find_donor(spec, self.specs, donatable)

    def _claim_replacements(self, job: _Job, t: float,
                            retrying: bool = False) -> None:
        """Fill this recovery's open slots — *mechanism only*; the
        claim-vs-preempt-vs-shrink-vs-wait ladder is the shared
        RecoveryPlanner's. Leaves the job in RESCHEDULE or WAITING.
        ``retrying`` marks a re-attempt from the WAITING state (wait
        bookkeeping continues instead of restarting)."""
        spec, view = job.spec, self._view(job)
        avoid = self._avoid_domains(job)

        def _cstate() -> ClusterState:
            eta = self._next_repair()
            return ClusterState(
                n_assigned=len(view.assigned),
                n_target=len(view.assigned) + job.pending_replace,
                min_nodes=spec.min_nodes,
                free_supply=self.topo.claimable_supply(),
                donor_available=self._find_donor(spec) is not None,
                repair_eta_s=max(eta - t, 0.0) if eta is not None else None,
                wait_allowed=True,
                has_ring_backup=job.pol.has_ring_backup,
                topology_changed=job.escalate,
                progress_at_risk_s=job.done - job.last_ckpt,
                remaining_s=job.need - job.done)

        def _claim() -> bool:
            got = self.sched.claim_replacement(spec.name, set(), avoid)
            if got is None:
                return False
            job.pending_replace -= 1
            return True

        def _preempt() -> bool:
            donor = self._find_donor(spec)
            if donor is None:
                return False
            self.sched.donate(donor, spec.name)
            self._preempt_donor(self.jobs[donor], t)
            job.counts["donations_taken"] += 1
            self.counts["preemptions"] += 1
            job.pending_replace -= 1
            return True

        def _shrink() -> None:
            # run shrunk: the survivors reshard from the store
            job.counts["shrinks"] += 1
            job.escalate = True
            job.pending_replace = 0

        # a parked recovery re-enters this ladder on every tick; scan supply
        # and donors once here for the log gate (fill_slots' per-iteration
        # _cstate re-scan stays — claims consume supply mid-fill) and only
        # log the retries that can actually move
        record = not retrying or self.topo.claimable_supply() > 0 \
            or self._find_donor(spec) is not None
        outcome = fill_slots(
            self.planner,
            Incident("retry" if retrying else "fault", t,
                     mid_recovery_join=job.escalate),
            _cstate,
            RecoveryExecutor(missing=lambda: job.pending_replace,
                             try_claim=_claim, try_preempt=_preempt,
                             do_shrink=_shrink, do_wait=lambda: None),
            costs=job.cost_model, job=spec.name, record=record)
        if outcome == PLAN_WAITING:
            # below the elastic floor and the pool is dry: stall the
            # recovery until repairs land (or a donor frees up)
            job.state = WAITING
            job.until = math.inf
            if not retrying:
                job.wait_start = t
                job.counts["waits"] += 1
            self._touch(job)
            return
        if retrying:
            job.wait_s += t - job.wait_start
            job.wait_s_in_open += t - job.wait_start
        job.state = RESCHEDULE
        job.until = t + job.pol.evict_reschedule_s
        self._maybe_prefetch(job, t)
        self._touch(job)

    def _maybe_prefetch(self, job: _Job, t: float) -> None:
        """Speculative restore prefetch: while the job sits in its
        reschedule window (slot filling, rank rebinding), start streaming
        the full store checkpoint on the shared NAS so the restore leg only
        pays whatever hasn't drained yet. Only fired when the planner's tier
        ranking already points at the store — prefetching a cache or
        ring-backup restore would burn shared bandwidth for nothing."""
        if not self.cfg.restore_prefetch or job.prefetch_flow is not None \
                or job.prefetch_done:
            return
        src = self.planner.choose_restore_source(
            inplace=job.inplace, escalated=job.escalate,
            has_ring_backup=job.pol.has_ring_backup)
        if src != SRC_STORE:
            return
        job.counts["prefetch_started"] += 1
        job.prefetch_flow = self._nas_start(
            t, job.spec.ckpt_bytes, f"{job.spec.name}:prefetch", job)

    def _open_planned_reshard(self, job: _Job, t: float) -> None:
        """A planned topology change (preemption donation or regrow): roll
        back to the last durable checkpoint and reshard through the store.
        No detect phase — nothing failed."""
        if job.save_flow is not None:
            self._nas_cancel(job.save_flow[0])
            job.save_flow = None
            job.counts["saves_torn"] += 1
        job.state = RESCHEDULE
        job.inplace = False
        job.escalate = True                 # reshard == store restore
        job.recovery_t0 = t
        job.pending_replace = 0
        job.wait_s_in_open = 0.0
        job.victim_racks = []
        job.until = t + job.pol.evict_reschedule_s
        self._maybe_prefetch(job, t)
        self._touch(job)

    def _preempt_donor(self, donor: _Job, t: float) -> None:
        """The donor lost a machine to a higher-priority job."""
        donor.counts["donations_given"] += 1
        self._open_planned_reshard(donor, t)

    def _maybe_regrow(self, t: float, shrunk: List[_Job]) -> None:
        """Repairs landed or capacity freed: shrunken RUNNING jobs reclaim
        machines, highest priority first, whenever the planner scores the
        reshard (rollback + store restore) cheaper than the throughput still
        being lost while degraded. This is the regrow-on-repair rung fleet
        jobs historically never took (they stayed shrunk for life).
        ``shrunk`` comes from the caller: the legacy loop rescans every job,
        the indexed loop reads its maintained shrunk set — same candidates
        either way (the sort below fixes the order)."""
        for job in sorted(shrunk,
                          key=lambda j: (-j.spec.priority,
                                         self.sched.submit_order(
                                             j.spec.name))):
            spec, view = job.spec, self._view(job)
            supply = self.topo.claimable_supply()
            if supply <= 0:
                return
            plan = self.planner.plan_regrow(
                ClusterState(
                    n_assigned=len(view.assigned), n_target=spec.n_nodes,
                    min_nodes=spec.min_nodes, free_supply=supply,
                    progress_at_risk_s=job.done - job.last_ckpt,
                    remaining_s=job.need - job.done),
                t=t, costs=job.cost_model, job=spec.name)
            if plan.decision != REGROW:
                continue
            got = 0
            while len(view.assigned) < spec.n_nodes and \
                    self.sched.claim_replacement(spec.name, set(), ()) \
                    is not None:
                got += 1
            if got:
                job.counts["regrows"] += 1
                self._open_planned_reshard(job, t)

    def _start_restore(self, job: _Job, t: float) -> None:
        job.state = RESTORE
        pol = job.pol
        # which TCE waterfall leg serves this restore is the planner's call
        job.restore_src = self.planner.choose_restore_source(
            inplace=job.inplace, escalated=job.escalate,
            has_ring_backup=pol.has_ring_backup)
        if job.restore_src != SRC_STORE and job.prefetch_flow is not None:
            # misprediction (the plan improved while rescheduling): drop
            # the speculative stream, the bytes were never needed
            self._nas_cancel(job.prefetch_flow)
            job.prefetch_flow = None
        if job.restore_src == SRC_STORE:
            if job.prefetch_done:
                # the speculative stream fully drained during the
                # reschedule window: the restore leg is free
                job.prefetch_done = False
                job.counts["prefetch_hits"] += 1
                job.until = t
            elif job.prefetch_flow is not None:
                # adopt the in-flight speculative stream as the restore
                # flow: only the residual bytes remain to drain
                job.restore_flow = job.prefetch_flow
                job.prefetch_flow = None
                job.counts["prefetch_hits"] += 1
                job.until = math.inf
            else:
                # reshard / double-fault / no-ring-backup policy: the
                # restore pulls the full checkpoint through the shared NAS
                # (a flow that contends with every other job's saves and
                # restores)
                job.until = math.inf    # ends when the NAS flow drains
                job.restore_flow = self._nas_start(
                    t, job.spec.ckpt_bytes, f"{job.spec.name}:restore", job)
        elif job.restore_src == SRC_CACHE:
            job.until = t + pol.inplace_restart_s + pol.restore_cache_s
        else:
            job.until = t + pol.restore_backup_s
        self._touch(job)

    def _close_recovery(self, job: _Job, t: float) -> None:
        view = self._view(job)
        src = job.restore_src
        job.restore_sources[src] = job.restore_sources.get(src, 0) + 1
        job.lost_s += job.done - job.last_ckpt
        job.done = job.last_ckpt
        job.next_ckpt = job.done + job.spec.ckpt_interval_s
        view.rebind_ranks(list(view.assigned))
        job.restart_times.append(t - job.recovery_t0 - job.wait_s_in_open)
        job.downtime_s += t - job.recovery_t0
        if job.prefetch_flow is not None:       # never adopted: stale
            self._nas_cancel(job.prefetch_flow)
            job.prefetch_flow = None
        job.prefetch_done = False
        job.state = RUNNING
        job.until = math.inf
        self._touch(job)

    # -- fault dispatch -------------------------------------------------- #
    def _handle_incident(self, t: float, evs: List[FaultEvent]) -> None:
        """Dispatch one incident: a single fault, or the member events of a
        same-(t, domain) correlated outage coalesced by
        :func:`group_domain_incidents`. Members are processed in the queue's
        stable FIFO order, exactly as a one-at-a-time drain would (pinned by
        test): the first member hitting each running job opens its recovery,
        the rest join that open transaction and escalate it to the store
        path."""
        if self.tee is not None:
            # Eagle Eye: degradations (slow, not dead) are only visible in
            # the metric streams — divert them to the streaming TEE; hard
            # crashes keep the immediate path (the gang scheduler sees the
            # process die, no detector needed)
            streamed = [ev for ev in evs if self._streamable(ev)]
            evs = [ev for ev in evs if not self._streamable(ev)]
            if streamed:
                self._observe_stream(t, streamed)
        for ev in evs:
            self._handle_fault(t, ev)

    # -- streaming-TEE path (Eagle Eye) ----------------------------------- #
    def _streamable(self, ev: FaultEvent) -> bool:
        """Degradation on a node a running job owns: detectable only by
        watching that job's metric stream."""
        if not ev.degrades_only:
            return False
        node = self.topo.nodes.get(ev.node)
        owner = self.topo.owner_of(ev.node)
        if node is None or owner is None or owner not in self.jobs \
                or node.state not in (NodeState.HEALTHY, NodeState.DEGRADED):
            return False
        return self.jobs[owner].state in (RUNNING, STALLED)

    def _observe_stream(self, t: float, evs: List[FaultEvent]) -> None:
        """Score the affected jobs' streams in one vectorized pass; firing
        verdicts enter the cross-job correlator, which groups them by
        failure domain and schedules one flush per domain group."""
        obs: List[StreamObservation] = []
        seen: Set[str] = set()
        for ev in evs:
            owner = self.topo.owner_of(ev.node)
            job = self.jobs[owner]
            if ev.domain is not None:
                job.counts["domain_hits"] += 1
                self.correlated.setdefault((t, ev.domain), set()).add(owner)
            if owner in seen:
                continue              # one stream per job per incident
            seen.add(owner)
            view = self._view(job)
            assigned = list(view.assigned)
            rank = assigned.index(ev.node) if ev.node in assigned else 0
            obs.append(StreamObservation(
                job=owner, n_ranks=len(assigned), rank=rank, node=ev.node,
                domain=ev.domain or self.topo.domain_of(ev.node),
                category=ev.category, degrades_only=True))
        for anom in self.tee.observe(t, obs):
            deadline = self.tee_correlator.add(anom)
            if deadline is not None:
                self.events.push(deadline, ("tee_flush", anom.domain))

    def _handle_tee_flush(self, t: float, domain: str) -> None:
        """A domain correlation window closed: plan ONCE for the whole
        domain-level incident (confidence-weighted), then execute per
        affected job."""
        inc = self.tee_correlator.flush(domain)
        if inc is None:
            return
        live = [n for n in inc.jobs
                if self.jobs[n].state in (RUNNING, STALLED)]
        owned = {n: [v for v in inc.victims if self.topo.owner_of(v) == n]
                 for n in live}
        pinc = Incident(kind="tee", t=t, victims=inc.victims,
                        categories=inc.categories, confidence=inc.confidence)
        if not live:
            self.tee_incidents.append(self._tee_entry(inc, "no_live_job"))
            return
        # one confidence-weighted plan for the domain (first job's view
        # stands in for the gang; per-job slot filling stays mechanism)
        job0 = self.jobs[live[0]]
        view0 = self._view(job0)
        eta = self._next_repair()
        st = ClusterState(
            n_assigned=len(view0.assigned) - len(owned[live[0]]),
            n_target=len(view0.assigned),
            min_nodes=job0.spec.min_nodes,
            free_supply=self.topo.claimable_supply(),
            donor_available=self._find_donor(job0.spec) is not None,
            repair_eta_s=max(eta - t, 0.0) if eta is not None else None,
            wait_allowed=True,
            has_ring_backup=job0.pol.has_ring_backup,
            progress_at_risk_s=job0.done - job0.last_ckpt,
            remaining_s=job0.need - job0.done)
        plan = self.planner.plan(pinc, st, costs=job0.cost_model,
                                 job="+".join(live))
        evict = plan.decision != RECOVER_IN_PLACE
        for name in live:
            job = self.jobs[name]
            victims = owned[name]
            if evict:
                for v in victims:     # cordon now: attribution is trusted
                    node = self.topo.nodes[v]
                    node.state = NodeState.DEGRADED
                    node.fail_category = inc.categories[0]
                    node.repair_at = t + self.topo.repair_s
            self.counts["job_faults"] += 1
            job.counts["faults_hit"] += 1
            # detection was already paid on the stream (flush fires after
            # the firing window closed): no extra drawn detect time
            self._open_recovery(job, t, victims if evict else [],
                                inplace=not evict, detect_s=0.0)
        self.tee_incidents.append(self._tee_entry(inc, plan.decision))

    @staticmethod
    def _tee_entry(inc, decision: str) -> dict:
        return {"t_open": round(inc.t_open, 3), "domain": inc.domain,
                "jobs": list(inc.jobs), "victims": list(inc.victims),
                "confidence": inc.confidence,
                "n_anomalies": inc.n_anomalies,
                "categories": list(inc.categories),
                "decision": decision}

    def _handle_fault(self, t: float, ev: FaultEvent) -> None:
        node = self.topo.nodes.get(ev.node)
        owner = self.topo.owner_of(ev.node)
        if node is None or owner is None or owner not in self.jobs \
                or node.state not in (NodeState.HEALTHY, NodeState.DEGRADED):
            self.counts["idle_faults"] += 1
            return
        job = self.jobs[owner]
        if not job.active:
            self.counts["idle_faults"] += 1
            return
        attributable = (ev.degrades_only or ev.domain is not None
                        or ev.category in NODE_ATTRIBUTABLE)
        if attributable:
            node.state = (NodeState.DEGRADED if ev.degrades_only
                          else NodeState.FAILED)
            node.fail_category = ev.category
            node.repair_at = t + self.topo.repair_s
        if ev.domain is not None:
            job.counts["domain_hits"] += 1
            self.correlated.setdefault((t, ev.domain), set()).add(owner)
        # tier-correlated outage: the peer-ring backups live in the same
        # rack failure domain as the victims, so a domain-tagged event takes
        # the ring tier down with the nodes — escalate straight to the
        # durable store tiers
        tier_corr = self.cfg.tier_correlated and ev.domain is not None
        victims = [ev.node] if attributable else []
        if job.state in (RUNNING, STALLED):
            self.counts["job_faults"] += 1
            job.counts["faults_hit"] += 1
            self._open_recovery(job, t, victims, inplace=not attributable)
            if tier_corr:
                job.escalate = True
        else:                                   # lands in an open recovery
            job.counts["absorbed"] += 1
            if tier_corr:
                job.escalate = True
            if not attributable:
                return
            self._evict_and_note(job, t, victims)
            job.escalate = True                 # double fault -> store path
            if job.state == DETECT:
                return                          # handled when checks finish
            if job.state == RESTORE and job.restore_flow is not None:
                self._nas_cancel(job.restore_flow)
                job.restore_flow = None
            if job.state == WAITING:
                return                          # retried on the next repair
            self._claim_replacements(job, t)

    # -- timed-phase transitions ----------------------------------------- #
    def _advance_phase(self, job: _Job, t: float) -> None:
        if job.state == STALLED:
            job.state = RUNNING
            job.until = math.inf
            self._touch(job)
        elif job.state == DETECT:
            if job.inplace:
                self._start_restore(job, t)   # no eviction: restart in place
            else:
                self._claim_replacements(job, t)
        elif job.state == RESCHEDULE:
            self._start_restore(job, t)
        elif job.state == RESTORE:          # fixed-cost restore finished
            job.state = WARMUP
            job.until = t + job.pol.warmup_s
            self._touch(job)
        elif job.state == WARMUP:
            self._close_recovery(job, t)

    def _retry_waiting(self, job: _Job, t: float) -> None:
        """Re-run the whole escalation ladder for a stalled recovery: a
        repaired machine, a freed spare or a donor back in RUNNING state can
        all unblock it (the preemption rung stays live while waiting)."""
        self._claim_replacements(job, t, retrying=True)

    # -- progress markers -------------------------------------------------- #
    def _marker(self, job: _Job) -> float:
        return min(job.next_ckpt, job.need)

    def _at_marker(self, job: _Job, t: float) -> None:
        spec = job.spec
        if job.done >= job.need - _EPS:
            job.state = DONE
            job.finished_at = t
            job.final_nodes = len(self._view(job).assigned)
            job.until = math.inf
            if job.save_flow is not None:
                self._nas_cancel(job.save_flow[0])
                job.save_flow = None
            self._touch(job)
            self.sched.complete(spec.name)
            self._try_admit(t)
            return
        if job.done >= job.next_ckpt - _EPS:
            if job.save_flow is not None:
                # previous async save still draining (NAS contention):
                # skip this cadence tick rather than stacking flows
                job.counts["saves_skipped"] += 1
                job.next_ckpt = job.done + spec.ckpt_interval_s
                self._touch(job)
                return
            job.counts["saves_started"] += 1
            job.save_flow = (self._nas_start(t, spec.ckpt_bytes,
                                             f"{spec.name}:save", job),
                             job.done)
            job.next_ckpt = job.done + spec.ckpt_interval_s
            job.state = STALLED
            job.until = t + job.pol.ckpt_save_stall_s
            self._touch(job)

    # -- NAS flow completions --------------------------------------------- #
    def _nas_completions(self, t: float) -> None:
        """Indexed flow-completion dispatch: every drained fid goes straight
        to its owning job via ``_flow_owner`` instead of the all-jobs scan
        the legacy loop still runs. Background demotion flows (TieredStore
        step aging on the shared NAS) have no owning job."""
        for t_done, fid, _label in self.nas.take_completed(t):
            if fid in self._demote_fids:
                self._demote_fids.discard(fid)
                self.counts["demotions_drained"] += 1
                continue
            job = self._flow_owner.pop(fid, None)
            if job is None:
                continue
            if job.save_flow is not None and job.save_flow[0] == fid:
                job.last_ckpt = job.save_flow[1]
                job.save_flow = None
                job.counts["saves_durable"] += 1
            elif job.restore_flow == fid:
                job.restore_flow = None
                job.state = WARMUP
                job.until = t_done + job.pol.warmup_s
                self._touch(job)
            elif job.prefetch_flow == fid:
                # speculative stream drained before the restore leg
                # opened: the bytes are staged, the restore will be free
                job.prefetch_flow = None
                job.prefetch_done = True

    def _nas_completions_legacy(self, t: float) -> None:
        for t_done, fid, _label in self.nas.take_completed(t):
            if fid in self._demote_fids:
                self._demote_fids.discard(fid)
                self.counts["demotions_drained"] += 1
                continue
            self._flow_owner.pop(fid, None)
            for job in self.jobs.values():
                if job.save_flow is not None and job.save_flow[0] == fid:
                    job.last_ckpt = job.save_flow[1]
                    job.save_flow = None
                    job.counts["saves_durable"] += 1
                    break
                if job.restore_flow == fid:
                    job.restore_flow = None
                    job.state = WARMUP
                    job.until = t_done + job.pol.warmup_s
                    break
                if job.prefetch_flow == fid:
                    # speculative stream drained before the restore leg
                    # opened: the bytes are staged, the restore will be free
                    job.prefetch_flow = None
                    job.prefetch_done = True
                    break

    # -- main loop --------------------------------------------------------- #
    def run(self) -> dict:
        t0 = time.perf_counter()
        for spec in self.cfg.jobs:
            if spec.submit_at_s <= 0:
                if self.sched.submit(spec) is not None:
                    self._newly_admitted.append(spec.name)
        self._try_admit(0.0)
        if self.cfg.legacy_dispatch:
            self._run_legacy()
        else:
            self._run_indexed()
        self._wall_s = time.perf_counter() - t0
        return self._report()

    def _run_indexed(self) -> None:
        """Event-driven dispatch: O(1) done-count termination, the next
        deadline from the wakeup heap / marker vector / epoch-cached NAS
        predictor, and vectorized progress banking between control events.
        Produces the exact tick sequence (and so the exact report) of
        :meth:`_run_legacy`; only the per-tick cost differs."""
        n_jobs = len(self._by_idx)
        prof = self._prof
        guard = 0
        while self._n_done < n_jobs:
            guard += 1
            if guard > 5_000_000:
                raise RuntimeError("fleet loop did not converge")
            self._ticks += 1
            if prof is not None:
                tp = time.perf_counter()
            t_now = self.clock.seconds
            t_next = max(self._next_deadline(t_now), t_now)
            dt = t_next - t_now
            if dt > 0.0:
                # identical IEEE arithmetic to the legacy per-job loop:
                # done[i] += dt * rate[i], running jobs only
                np.add(self._done_arr, dt * self._rate_arr,
                       out=self._done_arr, where=self._running_arr)
            if prof is not None:
                prof["deadline_bank"] += time.perf_counter() - tp
            self.clock.advance_to(t_next)
            self._process(t_next)

    def _next_deadline(self, t_now: float) -> float:
        """Minimum over exactly the candidate deadlines the legacy scan
        collects, without the per-job Python loop: event-queue head, cached
        NAS completion, wakeup-heap top (timed recovery phases), one
        vectorized pass over running jobs' progress markers, and the repair
        bound whenever any job is pending/waiting/shrunk/starved."""
        cands: List[float] = []
        if self.events:
            cands.append(self.events.peek_time())
        nc = self._nas_next()
        if nc is not None:
            cands.append(nc)
        h = self._until_heap
        while h:
            until, i, g = h[0]
            if g != self._gen[i]:
                heapq.heappop(h)        # stale: the job was touched since
                continue
            cands.append(until)
            break
        # markers re-derive the exact legacy expression each tick (an
        # anchored fire-time pushed at touch-time would be ulps away from
        # the freshly-computed candidate and break the byte-identical tick
        # sequence); one numpy pass instead of a per-job Python loop
        m = self._running_arr & (self._rate_arr > 0.0)
        if m.any():
            fire = t_now + np.maximum(
                self._marker_arr[m] - self._done_arr[m], 0.0) \
                / self._rate_arr[m]
            cands.append(float(fire.min()))
        if (self._pending_set or self._waiting_set or self._shrunk_set
                or self._zero_rate):
            # a queued, parked, shrunken or starved job wakes on repairs
            nr = self._next_repair()
            if nr is not None:
                cands.append(nr)
        if not cands:
            raise RuntimeError(
                "fleet deadlock: no runnable job, no pending event "
                f"(states: {[j.state for j in self.jobs.values()]})")
        return min(cands)

    def _advance_due(self, t: float) -> None:
        """Pop every timed job whose deadline fired and advance it in
        job-index order — the same order (and the same lazy condition
        re-check) as the legacy all-jobs scan. A handler may arm a new
        same-tick deadline on another job (e.g. a preemption donor with a
        zero-length reschedule window): the legacy scan reaches that job in
        the same pass only if it sits later in index order, so newly due
        entries join the pass only when their index is still ahead; earlier
        ones are re-queued for the next tick."""
        h = self._until_heap
        due: List[int] = []             # min-heap of due job indices
        while h and h[0][0] <= t + _EPS:
            _until, i, g = heapq.heappop(h)
            if g == self._gen[i]:
                heapq.heappush(due, i)
        last = -1
        while due:
            i = heapq.heappop(due)
            if i <= last:               # re-armed duplicate: once per pass
                continue
            last = i
            job = self._by_idx[i]
            if job.until <= t + _EPS and job.state not in _UNTIMED:
                self._advance_phase(job, t)
            while h and h[0][0] <= t + _EPS:
                entry = heapq.heappop(h)
                _u2, i2, g2 = entry
                if g2 != self._gen[i2]:
                    continue
                if i2 > last:
                    heapq.heappush(due, i2)
                else:
                    heapq.heappush(h, entry)    # next tick, like legacy
                    break

    def _process(self, t: float) -> None:
        prof = self._prof
        if prof is not None:
            tp = time.perf_counter()
        self._nas_completions(t)
        if prof is not None:
            now = time.perf_counter()
            prof["nas"] += now - tp
            tp = now
        self.topo.repair_due(t)
        self._advance_due(t)
        if prof is not None:
            now = time.perf_counter()
            prof["phases"] += now - tp
            tp = now
        for i in sorted(self._waiting_set):
            job = self._by_idx[i]
            if job.state == WAITING:
                self._retry_waiting(job, t)
        # regrow runs after parked recoveries retried (a below-floor recovery
        # outranks a comfort regrow) and before new admissions (_try_admit)
        self._maybe_regrow(t, [self._by_idx[i]
                               for i in sorted(self._shrunk_set)])
        if prof is not None:
            now = time.perf_counter()
            prof["retry_regrow"] += now - tp
            tp = now
        # exact-condition vectorized prefilter over the running jobs, then
        # the per-job legacy re-check (an earlier marker can complete a job
        # and admit successors mid-pass)
        fired = np.flatnonzero(self._running_arr
                               & (self._done_arr >= self._marker_arr - _EPS))
        for i in fired:
            job = self._by_idx[int(i)]
            if job.state == RUNNING and job.done >= self._marker(job) - _EPS:
                self._at_marker(job, t)
        if prof is not None:
            now = time.perf_counter()
            prof["markers"] += now - tp
            tp = now
        self._dispatch_events(t)
        self._try_admit(t)
        if prof is not None:
            prof["events_admit"] += time.perf_counter() - tp

    def _run_legacy(self) -> None:
        """The poll-everything loop the indexed dispatcher replaced, kept
        verbatim for the same-machine A/B (``legacy_dispatch=True``): every
        wakeup rescans all jobs for candidate deadlines, termination rescans
        every state, and progress banks per job in Python."""
        guard = 0
        while any(j.state != DONE for j in self.jobs.values()):
            guard += 1
            if guard > 5_000_000:
                raise RuntimeError("fleet loop did not converge")
            self._ticks += 1
            t_now = self.clock.seconds
            cands: List[float] = []
            if self.events:
                cands.append(self.events.peek_time())
            nc = self.nas.next_completion()
            if nc is not None:
                cands.append(nc)
            waiting_or_pending = any(j.state in (PENDING, WAITING)
                                     for j in self.jobs.values())
            for job in self.jobs.values():
                if job.state == RUNNING:
                    view = self._view(job)
                    if len(view.assigned) < job.spec.n_nodes:
                        # shrunken job: wake at the next repair so the
                        # planner can take the regrow-on-repair rung
                        waiting_or_pending = True
                    r = job.rate(view)
                    if r > 0:
                        cands.append(
                            t_now + max(self._marker(job) - job.done, 0.0) / r)
                    else:
                        waiting_or_pending = True
                elif job.until < math.inf:
                    cands.append(job.until)
            if waiting_or_pending:
                nr = self._next_repair()
                if nr is not None:
                    cands.append(nr)
            if not cands:
                raise RuntimeError(
                    "fleet deadlock: no runnable job, no pending event "
                    f"(states: {[j.state for j in self.jobs.values()]})")
            t_next = max(min(cands), t_now)
            # bank productive progress for every running job
            dt = t_next - t_now
            for job in self.jobs.values():
                if job.state == RUNNING:
                    job.done += dt * job.rate(self._view(job))
            self.clock.advance_to(t_next)
            self._process_legacy(t_next)

    def _process_legacy(self, t: float) -> None:
        self._nas_completions_legacy(t)
        self.topo.repair_due(t)
        for job in self.jobs.values():
            if job.until <= t + _EPS and job.state not in (PENDING, RUNNING,
                                                           WAITING, DONE):
                self._advance_phase(job, t)
        for job in self.jobs.values():
            if job.state == WAITING:
                self._retry_waiting(job, t)
        # regrow runs after parked recoveries retried (a below-floor recovery
        # outranks a comfort regrow) and before new admissions (_try_admit)
        self._maybe_regrow(t, [
            j for j in self.jobs.values()
            if j.state == RUNNING and j.spec.name in self.sched.views
            and len(self._view(j).assigned) < j.spec.n_nodes])
        for job in self.jobs.values():
            if job.state == RUNNING and job.done >= self._marker(job) - _EPS:
                self._at_marker(job, t)
        self._dispatch_events(t)
        self._try_admit(t)

    def _dispatch_events(self, t: float) -> None:
        for group in group_domain_incidents(self.events.pop_due(t)):
            first = group[0][1]
            if isinstance(first, FaultEvent):
                self._handle_incident(t, [p for _t_ev, p in group])
            elif isinstance(first, tuple) and first[0] == "submit":
                if self.sched.submit(self.specs[first[1]]) is not None:
                    self._newly_admitted.append(first[1])
            elif isinstance(first, tuple) and first[0] == "tee_flush":
                self._handle_tee_flush(t, first[1])
            elif isinstance(first, tuple) and first[0] == "demote":
                # background TieredStore demotion: a flow on the shared NAS
                # no job owns — foreground saves/restores contend with it
                fid = self.nas.start(t, first[1], "tier:demote")
                self._demote_fids.add(fid)
                self.counts["demotions_started"] += 1

    # -- report ------------------------------------------------------------ #
    def _job_report(self, job: _Job) -> dict:
        spec = job.spec
        wall = max(job.finished_at - job.admitted_at, _EPS)
        return {
            "priority": spec.priority,
            "n_nodes": spec.n_nodes,
            "min_nodes": spec.min_nodes,
            "policy": job.pol.name,
            "submitted_at_s": round(spec.submit_at_s, 3),
            "admitted_at_s": round(job.admitted_at, 3),
            "finished_at_s": round(job.finished_at, 3),
            "queue_wait_s": round(job.admitted_at - spec.submit_at_s, 3),
            "end_to_end_days": round(wall / DAY_S, 6),
            "effective_time_ratio": round(job.need / wall, 4),
            "lost_steps": int(round(job.lost_s / spec.step_time_s)),
            "final_nodes": job.final_nodes,
            "recovery": {
                "restarts": len(job.restart_times),
                "mean_restart_s": round(float(np.mean(job.restart_times)), 1)
                if job.restart_times else 0.0,
                "total_downtime_s": round(job.downtime_s, 1),
                "waits_for_repair": job.counts["waits"],
                "repair_wait_s": round(job.wait_s, 1),
            },
            "restore_sources": dict(sorted(job.restore_sources.items())),
            **({"prefetch": {"started": job.counts["prefetch_started"],
                             "hits": job.counts["prefetch_hits"]}}
               if self.cfg.restore_prefetch else {}),
            "saves": {k.split("_", 1)[1]: v for k, v in job.counts.items()
                      if k.startswith("saves_")},
            "faults": {"hit": job.counts["faults_hit"],
                       "absorbed_in_recovery": job.counts["absorbed"],
                       "domain_hits": job.counts["domain_hits"]},
            "preemption": {"donations_given": job.counts["donations_given"],
                           "donations_taken": job.counts["donations_taken"]},
            "shrinks": job.counts["shrinks"],
            "regrows": job.counts["regrows"],
        }

    def _report(self) -> dict:
        cfg = self.cfg
        elapsed = max(self.clock.seconds, _EPS)
        goodput_node_s = sum(j.need * j.spec.n_nodes
                             for j in self.jobs.values())
        correlated = [
            {"t": round(t, 3), "domain": dom, "jobs": sorted(names)}
            for (t, dom), names in sorted(self.correlated.items())]
        report = {
            "engine": "fleet",
            "seed": self.seed,
            "config": {
                "n_nodes": cfg.n_nodes,
                "n_spares": cfg.n_spares,
                "nodes_per_rack": cfg.nodes_per_rack,
                "repair_hours": cfg.repair_hours,
                "nas_bw_total": cfg.nas_bw_total,
                "preemption": cfg.preemption,
                "mtbf_node_days": cfg.mtbf_node_days,
                "rack_mtbf_days": cfg.rack_mtbf_days,
                "n_jobs": len(cfg.jobs),
                **({"restore_prefetch": True} if cfg.restore_prefetch
                   else {}),
                **({"tier_correlated": True} if cfg.tier_correlated else {}),
                **({"demotion_flows": len(cfg.demotion_traffic)}
                   if cfg.demotion_traffic else {}),
            },
            "makespan_days": round(elapsed / DAY_S, 6),
            "fleet": {
                "utilization": round(goodput_node_s
                                     / (cfg.n_nodes * elapsed), 4),
                "goodput_node_days": round(goodput_node_s / DAY_S, 4),
                "preemptions": self.counts["preemptions"],
                "scheduler": dict(self.sched.stats),
                "nas": {"bw_total": cfg.nas_bw_total,
                        **dict(self.nas.stats),
                        **({"demotions": {
                            "started": self.counts["demotions_started"],
                            "drained": self.counts["demotions_drained"]}}
                           if cfg.demotion_traffic else {})},
            },
            "faults": {
                "injected": self.n_injected,
                "hit_jobs": self.counts["job_faults"],
                "idle": self.counts["idle_faults"],
                "unfired_at_completion": len(self.events),
            },
            "correlated_events": correlated,
            "jobs": {name: self._job_report(j)
                     for name, j in sorted(self.jobs.items())},
            # the shared RecoveryPlanner's structured decision log (every
            # job's recoveries interleaved on the one fleet timeline)
            "decisions": self.planner.log.to_report(cap=100),
            "one_clock": (self.topo.clock is self.clock
                          and self.events.clock is self.clock),
        }
        if self.tee is not None:
            report["tee"] = {
                "stats": dict(self.tee.stats),
                "correlation_window_s": cfg.tee_correlation_s,
                "n_domain_incidents": len(self.tee_incidents),
                "incidents": self.tee_incidents,
            }
        return report


def run_fleet(cfg: FleetConfig, seed: Optional[int] = None) -> dict:
    """Run one multi-job fleet simulation; returns its deterministic JSON
    report (shared schema, see :mod:`repro.report`). ``seed`` overrides
    ``cfg.seed``. Module-level overrides: :func:`set_force_legacy` flips
    every run onto the legacy dispatcher (the equivalence suite's hook);
    :func:`set_profile` attaches a volatile ``measured`` section (wall time,
    ticks, per-phase breakdown) without changing the report body."""
    from repro.report import finalize

    use_seed = cfg.seed if seed is None else seed
    if _FORCE_LEGACY and not cfg.legacy_dispatch:
        cfg = replace(cfg, legacy_dispatch=True)
    run = _FleetRun(cfg, use_seed)
    if _PROFILE and not cfg.legacy_dispatch:
        run._prof = {k: 0.0 for k in ("deadline_bank", "nas", "phases",
                                      "retry_regrow", "markers",
                                      "events_admit")}
    report = finalize(run.run(), engine="fleet", seed=use_seed)
    if _PROFILE:
        wall = max(run._wall_s, 1e-9)
        measured = {
            "dispatch": "legacy" if cfg.legacy_dispatch else "indexed",
            "ticks": run._ticks,
            "wall_s": round(run._wall_s, 6),
            "ticks_per_s": round(run._ticks / wall, 1),
        }
        if run._prof is not None:
            measured["profile_s"] = {k: round(v, 6)
                                     for k, v in sorted(run._prof.items())}
        report["measured"] = measured
    return report


def no_preemption(cfg: FleetConfig) -> FleetConfig:
    """The identical fleet (same jobs, same fault timeline) with preemption
    disabled — the baseline the priority_preemption preset compares against."""
    return replace(cfg, preemption=False)
