"""Fleet engine: N concurrent training jobs on ONE shared substrate.

Every run builds exactly one :class:`~repro.sim.clock.SimClock`, one
:class:`~repro.sim.topology.Topology` and one fault
:class:`~repro.sim.clock.EventQueue`; N modelled training jobs (the soak
engine's cost model, per job) advance on that single timeline:

* the :class:`~repro.fleet.scheduler.FleetScheduler` gang-schedules jobs,
  queues the ones that don't fit, and arbitrates every replacement claim
  through the topology's lease ledger — two recovering jobs can never be
  handed the same spare;
* a low-priority job can be **preempted**: elastically shrunk by one machine
  to unblock a high-priority job's recovery when the shared pool is dry
  (the donor pays a reshard — rollback to its last durable checkpoint and a
  restore through the store);
* checkpoint saves and store restores are **flows on one shared NAS**
  (:class:`~repro.core.tce.store.SharedBandwidth`, processor sharing): one
  job's restore waterfall visibly slows another job's async save, and a
  save that hasn't drained when a crash lands is torn (not durable);
* correlated faults carry their failure-domain tag, so a rack/switch outage
  hits every co-located job in the same event (reported per ``(t, domain)``
  group) and replacements avoid the failed domain.

The run is fully seeded and emits a deterministic JSON-able report with
per-job recovery/goodput sections and fleet-level utilization.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, replace
from typing import Dict, List, Optional, Set, Tuple

import numpy as np

from repro.core.tce.store import NAS_BW_PER_RANK, SharedBandwidth
from repro.recovery import (RECOVER_IN_PLACE, REGROW, SRC_CACHE, SRC_STORE,
                            ClusterState, CostModel, Incident,
                            RecoveryExecutor, RecoveryPlanner, fill_slots)
from repro.recovery.executor import WAITING as PLAN_WAITING
from repro.sim.clock import EventQueue, SimClock
from repro.sim.faults import (FaultEvent, FaultInjector, cascade_events,
                              domain_outage_schedule, get_mix,
                              group_domain_incidents, merge_schedules,
                              push_schedule)
from repro.sim.soak import DAY_S, NODE_ATTRIBUTABLE, SoakPolicy
from repro.sim.topology import NodeState, Topology
from repro.tee_stream import (CrossJobCorrelator, FleetStreamTEE,
                              StreamObservation)

from .scheduler import FleetScheduler, JobSpec

_EPS = 1e-6

# job lifecycle states; DETECT/RESCHEDULE/RESTORE/WARMUP are the phases of
# one open recovery transaction
PENDING, RUNNING, STALLED = "pending", "running", "stalled"
DETECT, RESCHEDULE, RESTORE, WARMUP = ("detect", "reschedule", "restore",
                                       "warmup")
WAITING, DONE = "waiting", "done"
_RECOVERY = frozenset({DETECT, RESCHEDULE, RESTORE, WARMUP, WAITING})


@dataclass(frozen=True)
class FleetConfig:
    """One fleet run: a shared cluster, N job specs, a fault environment."""
    jobs: Tuple[JobSpec, ...]
    n_nodes: int = 16
    n_spares: int = 4
    nodes_per_rack: int = 8
    racks_per_switch: int = 4
    repair_hours: float = 4.0
    # one shared NAS uplink (paper §IV-C per-rank bandwidth x a few ranks):
    # an 8 GB checkpoint drains in ~28 s solo, ~56 s with one contender
    nas_bw_total: float = 4 * NAS_BW_PER_RANK
    preemption: bool = True
    # stochastic fault environment (0 disables each source)
    mtbf_node_days: float = 0.0
    straggler_frac: float = 0.15
    p_cascade: float = 0.0
    cascade_window_s: float = 600.0
    rack_mtbf_days: float = 0.0
    horizon_days: float = 30.0
    scripted: Tuple[FaultEvent, ...] = ()        # deterministic extra events
    planner_policy: str = "transom"              # RecoveryPlanner policy
    fault_mix: str = "table1"                    # category mix (faults.MIXES)
    # streaming TEE (Eagle Eye): degradation faults are detected by scoring
    # the affected jobs' metric streams (vectorized, confidence-weighted,
    # cross-job correlated by failure domain) instead of firing instantly
    tee_stream: bool = False
    tee_correlation_s: float = 900.0             # domain correlation window
    # N-tier checkpoint hierarchy knobs (repro.recovery.tiers):
    # ``restore_prefetch`` speculatively streams the store checkpoint on the
    # shared NAS while a job is still rescheduling, so the restore leg only
    # pays the residual; ``tier_correlated`` models the peer-ring backup tier
    # sharing the rack failure domain — a rack outage takes the ring with it
    # and the recovery escalates straight to the durable store tiers
    restore_prefetch: bool = False
    tier_correlated: bool = False
    seed: int = 0


class _Job:
    """Runtime state of one job (spec + progress + open-recovery fields)."""

    def __init__(self, spec: JobSpec):
        self.spec = spec
        self.pol: SoakPolicy = spec.policy
        self.state = PENDING
        self.until = math.inf            # end of the current timed phase
        self.need = spec.ideal_hours * 3600.0
        self.done = 0.0                  # productive seconds banked
        self.last_ckpt = 0.0             # durable checkpoint (productive s)
        self.next_ckpt = spec.ckpt_interval_s
        self.save_flow: Optional[Tuple[int, float]] = None   # (fid, snapshot)
        self.restore_flow: Optional[int] = None
        self.prefetch_flow: Optional[int] = None  # speculative store stream
        self.prefetch_done = False
        # open recovery transaction
        self.inplace = False
        self.escalate = False
        self.recovery_t0 = 0.0
        self.pending_replace = 0
        self.wait_start = 0.0
        self.wait_s_in_open = 0.0
        self.restore_src = SRC_CACHE
        self.victim_racks: List[str] = []
        # lifetime stats
        self.admitted_at = math.inf
        self.finished_at = math.inf
        self.final_nodes = 0
        self.lost_s = 0.0
        self.restart_times: List[float] = []
        self.downtime_s = 0.0
        self.restore_sources: Dict[str, int] = {}
        self.counts = dict(faults_hit=0, absorbed=0, domain_hits=0,
                           shrinks=0, regrows=0, donations_given=0,
                           donations_taken=0, waits=0, saves_started=0,
                           saves_durable=0, saves_torn=0, saves_skipped=0,
                           prefetch_started=0, prefetch_hits=0)
        self.wait_s = 0.0
        # CostModel view of this job's policy for the shared planner
        self.cost_model = CostModel.from_soak_policy(self.pol)

    @property
    def active(self) -> bool:
        return self.state not in (PENDING, DONE)

    def rate(self, view) -> float:
        return len(view.assigned) / self.spec.n_nodes


class _FleetRun:
    def __init__(self, cfg: FleetConfig, seed: int):
        self.cfg = cfg
        self.seed = seed
        self.rng = np.random.default_rng(seed)
        self.clock = SimClock()
        self.topo = Topology(cfg.n_nodes, n_spares=cfg.n_spares,
                             repair_hours=cfg.repair_hours,
                             nodes_per_rack=cfg.nodes_per_rack,
                             racks_per_switch=cfg.racks_per_switch,
                             clock=self.clock, auto_assign=False)
        self.sched = FleetScheduler(self.topo)
        self.nas = SharedBandwidth(cfg.nas_bw_total)
        self.events = EventQueue(self.clock)
        self.jobs: Dict[str, _Job] = {}
        self.specs: Dict[str, JobSpec] = {}
        for spec in cfg.jobs:
            if spec.n_nodes > cfg.n_nodes:
                raise ValueError(f"{spec.name}: wants {spec.n_nodes} nodes, "
                                 f"fleet has {cfg.n_nodes}")
            self.specs[spec.name] = spec
            self.jobs[spec.name] = _Job(spec)
            if spec.submit_at_s > 0:
                self.events.push(spec.submit_at_s, ("submit", spec.name))
        schedule: List[FaultEvent] = list(cfg.scripted)
        weights = (None if cfg.fault_mix == "table1"
                   else dict(get_mix(cfg.fault_mix).weights))
        if cfg.mtbf_node_days > 0:
            primary = FaultInjector(
                cfg.n_nodes, cfg.mtbf_node_days,
                horizon_days=cfg.horizon_days,
                straggler_frac=cfg.straggler_frac, seed=seed,
                weights=weights).schedule()
            if cfg.p_cascade > 0:
                primary = cascade_events(
                    primary, list(self.topo.nodes), p_cascade=cfg.p_cascade,
                    recovery_window_s=cfg.cascade_window_s, seed=seed + 1,
                    weights=weights)
            schedule = merge_schedules(schedule, primary)
        if cfg.rack_mtbf_days > 0:
            schedule = merge_schedules(schedule, domain_outage_schedule(
                self.topo, "rack", cfg.rack_mtbf_days, cfg.horizon_days,
                seed=seed + 2))
        self.n_injected = push_schedule(self.events, schedule)
        # ONE recovery brain across every job: claim-vs-preempt-vs-shrink-
        # vs-wait and regrow-on-repair are planned here, per-job costs
        # supplied per call; the engine below is mechanism only
        self.planner = RecoveryPlanner(cfg.planner_policy)
        self.counts = dict(idle_faults=0, job_faults=0, preemptions=0)
        # (t, domain) -> set of job names hit by that correlated event
        self.correlated: Dict[Tuple[float, str], Set[str]] = {}
        # streaming TEE service + cross-job correlator (Eagle Eye)
        self.tee: Optional[FleetStreamTEE] = None
        self.tee_correlator: Optional[CrossJobCorrelator] = None
        self.tee_incidents: List[dict] = []
        if cfg.tee_stream:
            self.tee = FleetStreamTEE(seed=seed)
            self.tee_correlator = CrossJobCorrelator(cfg.tee_correlation_s)

    # ------------------------------------------------------------------ #
    def _view(self, job: _Job):
        return self.sched.views[job.spec.name]

    def _detect_s(self, pol: SoakPolicy) -> float:
        if pol.weekend_frac > 0 and self.rng.random() < pol.weekend_frac:
            return pol.weekend_detect_s
        return float(self.rng.exponential(pol.detect_mean_s))

    def _next_repair(self) -> Optional[float]:
        due = self.topo.next_repair_at()
        if due is None:
            return None
        return max(due, self.clock.seconds + 1.0)

    def _try_admit(self, t: float) -> None:
        self.sched.try_admit()
        for name in self.sched.views:
            job = self.jobs[name]
            if job.state == PENDING:
                job.state = RUNNING
                job.admitted_at = t
                job.next_ckpt = job.spec.ckpt_interval_s

    # -- recovery transaction ------------------------------------------- #
    def _open_recovery(self, job: _Job, t: float, victims: List[str],
                       inplace: bool,
                       detect_s: Optional[float] = None) -> None:
        """Open one recovery transaction. ``detect_s`` overrides the drawn
        detection time — streaming-TEE incidents already paid detection on
        the metric stream, so they open with ``detect_s=0.0``."""
        if job.save_flow is not None:
            # the crash tears the in-flight save: it never becomes durable
            self.nas.cancel(job.save_flow[0])
            job.save_flow = None
            job.counts["saves_torn"] += 1
        job.state = DETECT
        job.inplace = inplace
        job.escalate = False
        job.recovery_t0 = t
        job.pending_replace = 0
        job.wait_s_in_open = 0.0
        job.victim_racks = []
        if detect_s is None:
            detect_s = self._detect_s(job.pol)
        job.until = t + detect_s + job.pol.error_check_s
        self._evict_and_note(job, t, victims)

    def _evict_and_note(self, job: _Job, t: float,
                        victims: List[str]) -> None:
        view = self._view(job)
        for v in victims:
            job.victim_racks.append(self.topo.domain_of(v))
            view.evict(v, t)
            job.pending_replace += 1

    def _avoid_domains(self, job: _Job) -> Set[str]:
        # 2+ victims in one rack point at a correlated root cause: keep
        # replacements out of that failure domain (domain-tagged events
        # already recorded each victim's rack here too)
        hits: Dict[str, int] = {}
        for r in job.victim_racks:
            hits[r] = hits.get(r, 0) + 1
        return {r for r, c in hits.items() if c >= 2}

    def _find_donor(self, spec) -> Optional[str]:
        """Mechanism: the scheduler names the lowest-priority shrinkable job
        among those not currently mid-recovery."""
        if not self.cfg.preemption:
            return None
        donatable = {n for n, j in self.jobs.items()
                     if j.state in (RUNNING, STALLED)}
        return self.sched.find_donor(spec, self.specs, donatable)

    def _claim_replacements(self, job: _Job, t: float,
                            retrying: bool = False) -> None:
        """Fill this recovery's open slots — *mechanism only*; the
        claim-vs-preempt-vs-shrink-vs-wait ladder is the shared
        RecoveryPlanner's. Leaves the job in RESCHEDULE or WAITING.
        ``retrying`` marks a re-attempt from the WAITING state (wait
        bookkeeping continues instead of restarting)."""
        spec, view = job.spec, self._view(job)
        avoid = self._avoid_domains(job)

        def _cstate() -> ClusterState:
            eta = self._next_repair()
            return ClusterState(
                n_assigned=len(view.assigned),
                n_target=len(view.assigned) + job.pending_replace,
                min_nodes=spec.min_nodes,
                free_supply=self.topo.claimable_supply(),
                donor_available=self._find_donor(spec) is not None,
                repair_eta_s=max(eta - t, 0.0) if eta is not None else None,
                wait_allowed=True,
                has_ring_backup=job.pol.has_ring_backup,
                topology_changed=job.escalate,
                progress_at_risk_s=job.done - job.last_ckpt,
                remaining_s=job.need - job.done)

        def _claim() -> bool:
            got = self.sched.claim_replacement(spec.name, set(), avoid)
            if got is None:
                return False
            job.pending_replace -= 1
            return True

        def _preempt() -> bool:
            donor = self._find_donor(spec)
            if donor is None:
                return False
            self.sched.donate(donor, spec.name)
            self._preempt_donor(self.jobs[donor], t)
            job.counts["donations_taken"] += 1
            self.counts["preemptions"] += 1
            job.pending_replace -= 1
            return True

        def _shrink() -> None:
            # run shrunk: the survivors reshard from the store
            job.counts["shrinks"] += 1
            job.escalate = True
            job.pending_replace = 0

        # a parked recovery re-enters this ladder on every tick; scan supply
        # and donors once here for the log gate (fill_slots' per-iteration
        # _cstate re-scan stays — claims consume supply mid-fill) and only
        # log the retries that can actually move
        record = not retrying or self.topo.claimable_supply() > 0 \
            or self._find_donor(spec) is not None
        outcome = fill_slots(
            self.planner,
            Incident("retry" if retrying else "fault", t,
                     mid_recovery_join=job.escalate),
            _cstate,
            RecoveryExecutor(missing=lambda: job.pending_replace,
                             try_claim=_claim, try_preempt=_preempt,
                             do_shrink=_shrink, do_wait=lambda: None),
            costs=job.cost_model, job=spec.name, record=record)
        if outcome == PLAN_WAITING:
            # below the elastic floor and the pool is dry: stall the
            # recovery until repairs land (or a donor frees up)
            job.state = WAITING
            job.until = math.inf
            if not retrying:
                job.wait_start = t
                job.counts["waits"] += 1
            return
        if retrying:
            job.wait_s += t - job.wait_start
            job.wait_s_in_open += t - job.wait_start
        job.state = RESCHEDULE
        job.until = t + job.pol.evict_reschedule_s
        self._maybe_prefetch(job, t)

    def _maybe_prefetch(self, job: _Job, t: float) -> None:
        """Speculative restore prefetch: while the job sits in its
        reschedule window (slot filling, rank rebinding), start streaming
        the full store checkpoint on the shared NAS so the restore leg only
        pays whatever hasn't drained yet. Only fired when the planner's tier
        ranking already points at the store — prefetching a cache or
        ring-backup restore would burn shared bandwidth for nothing."""
        if not self.cfg.restore_prefetch or job.prefetch_flow is not None \
                or job.prefetch_done:
            return
        src = self.planner.choose_restore_source(
            inplace=job.inplace, escalated=job.escalate,
            has_ring_backup=job.pol.has_ring_backup)
        if src != SRC_STORE:
            return
        job.counts["prefetch_started"] += 1
        job.prefetch_flow = self.nas.start(
            t, job.spec.ckpt_bytes, f"{job.spec.name}:prefetch")

    def _open_planned_reshard(self, job: _Job, t: float) -> None:
        """A planned topology change (preemption donation or regrow): roll
        back to the last durable checkpoint and reshard through the store.
        No detect phase — nothing failed."""
        if job.save_flow is not None:
            self.nas.cancel(job.save_flow[0])
            job.save_flow = None
            job.counts["saves_torn"] += 1
        job.state = RESCHEDULE
        job.inplace = False
        job.escalate = True                 # reshard == store restore
        job.recovery_t0 = t
        job.pending_replace = 0
        job.wait_s_in_open = 0.0
        job.victim_racks = []
        job.until = t + job.pol.evict_reschedule_s
        self._maybe_prefetch(job, t)

    def _preempt_donor(self, donor: _Job, t: float) -> None:
        """The donor lost a machine to a higher-priority job."""
        donor.counts["donations_given"] += 1
        self._open_planned_reshard(donor, t)

    def _maybe_regrow(self, t: float) -> None:
        """Repairs landed or capacity freed: shrunken RUNNING jobs reclaim
        machines, highest priority first, whenever the planner scores the
        reshard (rollback + store restore) cheaper than the throughput still
        being lost while degraded. This is the regrow-on-repair rung fleet
        jobs historically never took (they stayed shrunk for life)."""
        shrunk = [j for j in self.jobs.values()
                  if j.state == RUNNING and j.spec.name in self.sched.views
                  and len(self._view(j).assigned) < j.spec.n_nodes]
        for job in sorted(shrunk,
                          key=lambda j: (-j.spec.priority,
                                         self.sched.submit_order(
                                             j.spec.name))):
            spec, view = job.spec, self._view(job)
            supply = self.topo.claimable_supply()
            if supply <= 0:
                return
            plan = self.planner.plan_regrow(
                ClusterState(
                    n_assigned=len(view.assigned), n_target=spec.n_nodes,
                    min_nodes=spec.min_nodes, free_supply=supply,
                    progress_at_risk_s=job.done - job.last_ckpt,
                    remaining_s=job.need - job.done),
                t=t, costs=job.cost_model, job=spec.name)
            if plan.decision != REGROW:
                continue
            got = 0
            while len(view.assigned) < spec.n_nodes and \
                    self.sched.claim_replacement(spec.name, set(), ()) \
                    is not None:
                got += 1
            if got:
                job.counts["regrows"] += 1
                self._open_planned_reshard(job, t)

    def _start_restore(self, job: _Job, t: float) -> None:
        job.state = RESTORE
        pol = job.pol
        # which TCE waterfall leg serves this restore is the planner's call
        job.restore_src = self.planner.choose_restore_source(
            inplace=job.inplace, escalated=job.escalate,
            has_ring_backup=pol.has_ring_backup)
        if job.restore_src != SRC_STORE and job.prefetch_flow is not None:
            # misprediction (the plan improved while rescheduling): drop
            # the speculative stream, the bytes were never needed
            self.nas.cancel(job.prefetch_flow)
            job.prefetch_flow = None
        if job.restore_src == SRC_STORE:
            if job.prefetch_done:
                # the speculative stream fully drained during the
                # reschedule window: the restore leg is free
                job.prefetch_done = False
                job.counts["prefetch_hits"] += 1
                job.until = t
            elif job.prefetch_flow is not None:
                # adopt the in-flight speculative stream as the restore
                # flow: only the residual bytes remain to drain
                job.restore_flow = job.prefetch_flow
                job.prefetch_flow = None
                job.counts["prefetch_hits"] += 1
                job.until = math.inf
            else:
                # reshard / double-fault / no-ring-backup policy: the
                # restore pulls the full checkpoint through the shared NAS
                # (a flow that contends with every other job's saves and
                # restores)
                job.until = math.inf    # ends when the NAS flow drains
                job.restore_flow = self.nas.start(
                    t, job.spec.ckpt_bytes, f"{job.spec.name}:restore")
        elif job.restore_src == SRC_CACHE:
            job.until = t + pol.inplace_restart_s + pol.restore_cache_s
        else:
            job.until = t + pol.restore_backup_s

    def _close_recovery(self, job: _Job, t: float) -> None:
        view = self._view(job)
        src = job.restore_src
        job.restore_sources[src] = job.restore_sources.get(src, 0) + 1
        job.lost_s += job.done - job.last_ckpt
        job.done = job.last_ckpt
        job.next_ckpt = job.done + job.spec.ckpt_interval_s
        view.rebind_ranks(list(view.assigned))
        job.restart_times.append(t - job.recovery_t0 - job.wait_s_in_open)
        job.downtime_s += t - job.recovery_t0
        if job.prefetch_flow is not None:       # never adopted: stale
            self.nas.cancel(job.prefetch_flow)
            job.prefetch_flow = None
        job.prefetch_done = False
        job.state = RUNNING
        job.until = math.inf

    # -- fault dispatch -------------------------------------------------- #
    def _handle_incident(self, t: float, evs: List[FaultEvent]) -> None:
        """Dispatch one incident: a single fault, or the member events of a
        same-(t, domain) correlated outage coalesced by
        :func:`group_domain_incidents`. Members are processed in the queue's
        stable FIFO order, exactly as a one-at-a-time drain would (pinned by
        test): the first member hitting each running job opens its recovery,
        the rest join that open transaction and escalate it to the store
        path."""
        if self.tee is not None:
            # Eagle Eye: degradations (slow, not dead) are only visible in
            # the metric streams — divert them to the streaming TEE; hard
            # crashes keep the immediate path (the gang scheduler sees the
            # process die, no detector needed)
            streamed = [ev for ev in evs if self._streamable(ev)]
            evs = [ev for ev in evs if not self._streamable(ev)]
            if streamed:
                self._observe_stream(t, streamed)
        for ev in evs:
            self._handle_fault(t, ev)

    # -- streaming-TEE path (Eagle Eye) ----------------------------------- #
    def _streamable(self, ev: FaultEvent) -> bool:
        """Degradation on a node a running job owns: detectable only by
        watching that job's metric stream."""
        if not ev.degrades_only:
            return False
        node = self.topo.nodes.get(ev.node)
        owner = self.topo.owner_of(ev.node)
        if node is None or owner is None or owner not in self.jobs \
                or node.state not in (NodeState.HEALTHY, NodeState.DEGRADED):
            return False
        return self.jobs[owner].state in (RUNNING, STALLED)

    def _observe_stream(self, t: float, evs: List[FaultEvent]) -> None:
        """Score the affected jobs' streams in one vectorized pass; firing
        verdicts enter the cross-job correlator, which groups them by
        failure domain and schedules one flush per domain group."""
        obs: List[StreamObservation] = []
        seen: Set[str] = set()
        for ev in evs:
            owner = self.topo.owner_of(ev.node)
            job = self.jobs[owner]
            if ev.domain is not None:
                job.counts["domain_hits"] += 1
                self.correlated.setdefault((t, ev.domain), set()).add(owner)
            if owner in seen:
                continue              # one stream per job per incident
            seen.add(owner)
            view = self._view(job)
            assigned = list(view.assigned)
            rank = assigned.index(ev.node) if ev.node in assigned else 0
            obs.append(StreamObservation(
                job=owner, n_ranks=len(assigned), rank=rank, node=ev.node,
                domain=ev.domain or self.topo.domain_of(ev.node),
                category=ev.category, degrades_only=True))
        for anom in self.tee.observe(t, obs):
            deadline = self.tee_correlator.add(anom)
            if deadline is not None:
                self.events.push(deadline, ("tee_flush", anom.domain))

    def _handle_tee_flush(self, t: float, domain: str) -> None:
        """A domain correlation window closed: plan ONCE for the whole
        domain-level incident (confidence-weighted), then execute per
        affected job."""
        inc = self.tee_correlator.flush(domain)
        if inc is None:
            return
        live = [n for n in inc.jobs
                if self.jobs[n].state in (RUNNING, STALLED)]
        owned = {n: [v for v in inc.victims if self.topo.owner_of(v) == n]
                 for n in live}
        pinc = Incident(kind="tee", t=t, victims=inc.victims,
                        categories=inc.categories, confidence=inc.confidence)
        if not live:
            self.tee_incidents.append(self._tee_entry(inc, "no_live_job"))
            return
        # one confidence-weighted plan for the domain (first job's view
        # stands in for the gang; per-job slot filling stays mechanism)
        job0 = self.jobs[live[0]]
        view0 = self._view(job0)
        eta = self._next_repair()
        st = ClusterState(
            n_assigned=len(view0.assigned) - len(owned[live[0]]),
            n_target=len(view0.assigned),
            min_nodes=job0.spec.min_nodes,
            free_supply=self.topo.claimable_supply(),
            donor_available=self._find_donor(job0.spec) is not None,
            repair_eta_s=max(eta - t, 0.0) if eta is not None else None,
            wait_allowed=True,
            has_ring_backup=job0.pol.has_ring_backup,
            progress_at_risk_s=job0.done - job0.last_ckpt,
            remaining_s=job0.need - job0.done)
        plan = self.planner.plan(pinc, st, costs=job0.cost_model,
                                 job="+".join(live))
        evict = plan.decision != RECOVER_IN_PLACE
        for name in live:
            job = self.jobs[name]
            victims = owned[name]
            if evict:
                for v in victims:     # cordon now: attribution is trusted
                    node = self.topo.nodes[v]
                    node.state = NodeState.DEGRADED
                    node.fail_category = inc.categories[0]
                    node.repair_at = t + self.topo.repair_s
            self.counts["job_faults"] += 1
            job.counts["faults_hit"] += 1
            # detection was already paid on the stream (flush fires after
            # the firing window closed): no extra drawn detect time
            self._open_recovery(job, t, victims if evict else [],
                                inplace=not evict, detect_s=0.0)
        self.tee_incidents.append(self._tee_entry(inc, plan.decision))

    @staticmethod
    def _tee_entry(inc, decision: str) -> dict:
        return {"t_open": round(inc.t_open, 3), "domain": inc.domain,
                "jobs": list(inc.jobs), "victims": list(inc.victims),
                "confidence": inc.confidence,
                "n_anomalies": inc.n_anomalies,
                "categories": list(inc.categories),
                "decision": decision}

    def _handle_fault(self, t: float, ev: FaultEvent) -> None:
        node = self.topo.nodes.get(ev.node)
        owner = self.topo.owner_of(ev.node)
        if node is None or owner is None or owner not in self.jobs \
                or node.state not in (NodeState.HEALTHY, NodeState.DEGRADED):
            self.counts["idle_faults"] += 1
            return
        job = self.jobs[owner]
        if not job.active:
            self.counts["idle_faults"] += 1
            return
        attributable = (ev.degrades_only or ev.domain is not None
                        or ev.category in NODE_ATTRIBUTABLE)
        if attributable:
            node.state = (NodeState.DEGRADED if ev.degrades_only
                          else NodeState.FAILED)
            node.fail_category = ev.category
            node.repair_at = t + self.topo.repair_s
        if ev.domain is not None:
            job.counts["domain_hits"] += 1
            self.correlated.setdefault((t, ev.domain), set()).add(owner)
        # tier-correlated outage: the peer-ring backups live in the same
        # rack failure domain as the victims, so a domain-tagged event takes
        # the ring tier down with the nodes — escalate straight to the
        # durable store tiers
        tier_corr = self.cfg.tier_correlated and ev.domain is not None
        victims = [ev.node] if attributable else []
        if job.state in (RUNNING, STALLED):
            self.counts["job_faults"] += 1
            job.counts["faults_hit"] += 1
            self._open_recovery(job, t, victims, inplace=not attributable)
            if tier_corr:
                job.escalate = True
        else:                                   # lands in an open recovery
            job.counts["absorbed"] += 1
            if tier_corr:
                job.escalate = True
            if not attributable:
                return
            self._evict_and_note(job, t, victims)
            job.escalate = True                 # double fault -> store path
            if job.state == DETECT:
                return                          # handled when checks finish
            if job.state == RESTORE and job.restore_flow is not None:
                self.nas.cancel(job.restore_flow)
                job.restore_flow = None
            if job.state == WAITING:
                return                          # retried on the next repair
            self._claim_replacements(job, t)

    # -- timed-phase transitions ----------------------------------------- #
    def _advance_phase(self, job: _Job, t: float) -> None:
        if job.state == STALLED:
            job.state = RUNNING
            job.until = math.inf
        elif job.state == DETECT:
            if job.inplace:
                self._start_restore(job, t)   # no eviction: restart in place
            else:
                self._claim_replacements(job, t)
        elif job.state == RESCHEDULE:
            self._start_restore(job, t)
        elif job.state == RESTORE:          # fixed-cost restore finished
            job.state = WARMUP
            job.until = t + job.pol.warmup_s
        elif job.state == WARMUP:
            self._close_recovery(job, t)

    def _retry_waiting(self, job: _Job, t: float) -> None:
        """Re-run the whole escalation ladder for a stalled recovery: a
        repaired machine, a freed spare or a donor back in RUNNING state can
        all unblock it (the preemption rung stays live while waiting)."""
        self._claim_replacements(job, t, retrying=True)

    # -- progress markers -------------------------------------------------- #
    def _marker(self, job: _Job) -> float:
        return min(job.next_ckpt, job.need)

    def _at_marker(self, job: _Job, t: float) -> None:
        spec = job.spec
        if job.done >= job.need - _EPS:
            job.state = DONE
            job.finished_at = t
            job.final_nodes = len(self._view(job).assigned)
            job.until = math.inf
            if job.save_flow is not None:
                self.nas.cancel(job.save_flow[0])
                job.save_flow = None
            self.sched.complete(spec.name)
            self._try_admit(t)
            return
        if job.done >= job.next_ckpt - _EPS:
            if job.save_flow is not None:
                # previous async save still draining (NAS contention):
                # skip this cadence tick rather than stacking flows
                job.counts["saves_skipped"] += 1
                job.next_ckpt = job.done + spec.ckpt_interval_s
                return
            job.counts["saves_started"] += 1
            job.save_flow = (self.nas.start(t, spec.ckpt_bytes,
                                            f"{spec.name}:save"), job.done)
            job.next_ckpt = job.done + spec.ckpt_interval_s
            job.state = STALLED
            job.until = t + job.pol.ckpt_save_stall_s

    # -- NAS flow completions --------------------------------------------- #
    def _nas_completions(self, t: float) -> None:
        for t_done, fid, _label in self.nas.take_completed(t):
            for job in self.jobs.values():
                if job.save_flow is not None and job.save_flow[0] == fid:
                    job.last_ckpt = job.save_flow[1]
                    job.save_flow = None
                    job.counts["saves_durable"] += 1
                    break
                if job.restore_flow == fid:
                    job.restore_flow = None
                    job.state = WARMUP
                    job.until = t_done + job.pol.warmup_s
                    break
                if job.prefetch_flow == fid:
                    # speculative stream drained before the restore leg
                    # opened: the bytes are staged, the restore will be free
                    job.prefetch_flow = None
                    job.prefetch_done = True
                    break

    # -- main loop --------------------------------------------------------- #
    def run(self) -> dict:
        for spec in self.cfg.jobs:
            if spec.submit_at_s <= 0:
                self.sched.submit(spec)
        self._try_admit(0.0)
        guard = 0
        while any(j.state != DONE for j in self.jobs.values()):
            guard += 1
            if guard > 5_000_000:
                raise RuntimeError("fleet loop did not converge")
            t_now = self.clock.seconds
            cands: List[float] = []
            if self.events:
                cands.append(self.events.peek_time())
            nc = self.nas.next_completion()
            if nc is not None:
                cands.append(nc)
            waiting_or_pending = any(j.state in (PENDING, WAITING)
                                     for j in self.jobs.values())
            for job in self.jobs.values():
                if job.state == RUNNING:
                    view = self._view(job)
                    if len(view.assigned) < job.spec.n_nodes:
                        # shrunken job: wake at the next repair so the
                        # planner can take the regrow-on-repair rung
                        waiting_or_pending = True
                    r = job.rate(view)
                    if r > 0:
                        cands.append(
                            t_now + max(self._marker(job) - job.done, 0.0) / r)
                    else:
                        waiting_or_pending = True
                elif job.until < math.inf:
                    cands.append(job.until)
            if waiting_or_pending:
                nr = self._next_repair()
                if nr is not None:
                    cands.append(nr)
            if not cands:
                raise RuntimeError(
                    "fleet deadlock: no runnable job, no pending event "
                    f"(states: {[j.state for j in self.jobs.values()]})")
            t_next = max(min(cands), t_now)
            # bank productive progress for every running job
            dt = t_next - t_now
            for job in self.jobs.values():
                if job.state == RUNNING:
                    job.done += dt * job.rate(self._view(job))
            self.clock.advance_to(t_next)
            self._process(t_next)
        return self._report()

    def _process(self, t: float) -> None:
        self._nas_completions(t)
        self.topo.repair_due(t)
        for job in self.jobs.values():
            if job.until <= t + _EPS and job.state not in (PENDING, RUNNING,
                                                           WAITING, DONE):
                self._advance_phase(job, t)
        for job in self.jobs.values():
            if job.state == WAITING:
                self._retry_waiting(job, t)
        # regrow runs after parked recoveries retried (a below-floor recovery
        # outranks a comfort regrow) and before new admissions (_try_admit)
        self._maybe_regrow(t)
        for job in self.jobs.values():
            if job.state == RUNNING and job.done >= self._marker(job) - _EPS:
                self._at_marker(job, t)
        for group in group_domain_incidents(self.events.pop_due(t)):
            first = group[0][1]
            if isinstance(first, FaultEvent):
                self._handle_incident(t, [p for _t_ev, p in group])
            elif isinstance(first, tuple) and first[0] == "submit":
                self.sched.submit(self.specs[first[1]])
            elif isinstance(first, tuple) and first[0] == "tee_flush":
                self._handle_tee_flush(t, first[1])
        self._try_admit(t)

    # -- report ------------------------------------------------------------ #
    def _job_report(self, job: _Job) -> dict:
        spec = job.spec
        wall = max(job.finished_at - job.admitted_at, _EPS)
        return {
            "priority": spec.priority,
            "n_nodes": spec.n_nodes,
            "min_nodes": spec.min_nodes,
            "policy": job.pol.name,
            "submitted_at_s": round(spec.submit_at_s, 3),
            "admitted_at_s": round(job.admitted_at, 3),
            "finished_at_s": round(job.finished_at, 3),
            "queue_wait_s": round(job.admitted_at - spec.submit_at_s, 3),
            "end_to_end_days": round(wall / DAY_S, 6),
            "effective_time_ratio": round(job.need / wall, 4),
            "lost_steps": int(round(job.lost_s / spec.step_time_s)),
            "final_nodes": job.final_nodes,
            "recovery": {
                "restarts": len(job.restart_times),
                "mean_restart_s": round(float(np.mean(job.restart_times)), 1)
                if job.restart_times else 0.0,
                "total_downtime_s": round(job.downtime_s, 1),
                "waits_for_repair": job.counts["waits"],
                "repair_wait_s": round(job.wait_s, 1),
            },
            "restore_sources": dict(sorted(job.restore_sources.items())),
            **({"prefetch": {"started": job.counts["prefetch_started"],
                             "hits": job.counts["prefetch_hits"]}}
               if self.cfg.restore_prefetch else {}),
            "saves": {k.split("_", 1)[1]: v for k, v in job.counts.items()
                      if k.startswith("saves_")},
            "faults": {"hit": job.counts["faults_hit"],
                       "absorbed_in_recovery": job.counts["absorbed"],
                       "domain_hits": job.counts["domain_hits"]},
            "preemption": {"donations_given": job.counts["donations_given"],
                           "donations_taken": job.counts["donations_taken"]},
            "shrinks": job.counts["shrinks"],
            "regrows": job.counts["regrows"],
        }

    def _report(self) -> dict:
        cfg = self.cfg
        elapsed = max(self.clock.seconds, _EPS)
        goodput_node_s = sum(j.need * j.spec.n_nodes
                             for j in self.jobs.values())
        correlated = [
            {"t": round(t, 3), "domain": dom, "jobs": sorted(names)}
            for (t, dom), names in sorted(self.correlated.items())]
        report = {
            "engine": "fleet",
            "seed": self.seed,
            "config": {
                "n_nodes": cfg.n_nodes,
                "n_spares": cfg.n_spares,
                "nodes_per_rack": cfg.nodes_per_rack,
                "repair_hours": cfg.repair_hours,
                "nas_bw_total": cfg.nas_bw_total,
                "preemption": cfg.preemption,
                "mtbf_node_days": cfg.mtbf_node_days,
                "rack_mtbf_days": cfg.rack_mtbf_days,
                "n_jobs": len(cfg.jobs),
                **({"restore_prefetch": True} if cfg.restore_prefetch
                   else {}),
                **({"tier_correlated": True} if cfg.tier_correlated else {}),
            },
            "makespan_days": round(elapsed / DAY_S, 6),
            "fleet": {
                "utilization": round(goodput_node_s
                                     / (cfg.n_nodes * elapsed), 4),
                "goodput_node_days": round(goodput_node_s / DAY_S, 4),
                "preemptions": self.counts["preemptions"],
                "scheduler": dict(self.sched.stats),
                "nas": {"bw_total": cfg.nas_bw_total,
                        **dict(self.nas.stats)},
            },
            "faults": {
                "injected": self.n_injected,
                "hit_jobs": self.counts["job_faults"],
                "idle": self.counts["idle_faults"],
                "unfired_at_completion": len(self.events),
            },
            "correlated_events": correlated,
            "jobs": {name: self._job_report(j)
                     for name, j in sorted(self.jobs.items())},
            # the shared RecoveryPlanner's structured decision log (every
            # job's recoveries interleaved on the one fleet timeline)
            "decisions": self.planner.log.to_report(cap=100),
            "one_clock": (self.topo.clock is self.clock
                          and self.events.clock is self.clock),
        }
        if self.tee is not None:
            report["tee"] = {
                "stats": dict(self.tee.stats),
                "correlation_window_s": cfg.tee_correlation_s,
                "n_domain_incidents": len(self.tee_incidents),
                "incidents": self.tee_incidents,
            }
        return report


def run_fleet(cfg: FleetConfig, seed: Optional[int] = None) -> dict:
    """Run one multi-job fleet simulation; returns its deterministic JSON
    report (shared schema, see :mod:`repro.report`). ``seed`` overrides
    ``cfg.seed``."""
    from repro.report import finalize

    use_seed = cfg.seed if seed is None else seed
    return finalize(_FleetRun(cfg, use_seed).run(), engine="fleet",
                    seed=use_seed)


def no_preemption(cfg: FleetConfig) -> FleetConfig:
    """The identical fleet (same jobs, same fault timeline) with preemption
    disabled — the baseline the priority_preemption preset compares against."""
    return replace(cfg, preemption=False)
