"""CLI for the fleet control plane.

    python -m repro.fleet --list
    python -m repro.fleet --run two_jobs_rack_outage --seed 0
    python -m repro.fleet --run all --json reports.json

Flags and exit codes follow the shared convention in :mod:`repro.cli`.
Reports are byte-identical across runs at the same seed (the CI determinism
gate diffs two invocations).
"""
from __future__ import annotations

import sys
from typing import List, Optional

from repro.cli import catalog_main

from .presets import PRESETS, run_preset


def main(argv: Optional[List[str]] = None) -> int:
    return catalog_main(
        argv, prog="python -m repro.fleet",
        description="Run multi-job fleet scenarios (shared topology, shared "
                    "spare pool, contended NAS bandwidth).",
        catalog={n: p.description for n, p in PRESETS.items()},
        run=run_preset, what="fleet presets",
        add_args=lambda ap: ap.add_argument(
            "--profile", action="store_true",
            help="attach a measured wall-time / dispatcher phase breakdown "
                 "to each report (volatile: excluded from digests)"),
        run_kwargs=lambda args: {"profile": args.profile})


if __name__ == "__main__":
    sys.exit(main())
