"""CLI for the fleet control plane.

    python -m repro.fleet --list
    python -m repro.fleet --run two_jobs_rack_outage --seed 0
    python -m repro.fleet --run all --json reports.json

Reports are byte-identical across runs at the same seed (the CI determinism
gate diffs two invocations).
"""
from __future__ import annotations

import argparse
import json
import sys
from typing import List, Optional

from .presets import PRESETS, run_preset


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.fleet",
        description="Run multi-job fleet scenarios (shared topology, shared "
                    "spare pool, contended NAS bandwidth).")
    ap.add_argument("--list", action="store_true", help="list fleet presets")
    ap.add_argument("--run", metavar="NAME", help="preset name, or 'all'")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--json", metavar="PATH",
                    help="also write the report(s) to this file")
    args = ap.parse_args(argv)

    if args.list or not args.run:
        width = max(len(n) for n in PRESETS)
        for name in sorted(PRESETS):
            print(f"  {name:<{width}}  {PRESETS[name].description}")
        print(f"\n{len(PRESETS)} fleet presets. "
              f"Run one with: python -m repro.fleet --run <name>")
        return 0

    if args.run != "all" and args.run not in PRESETS:
        print(f"error: unknown fleet preset {args.run!r} (see --list)",
              file=sys.stderr)
        return 2
    names = sorted(PRESETS) if args.run == "all" else [args.run]
    reports = []
    for name in names:
        rep = run_preset(name, seed=args.seed)
        reports.append(rep)
        print(json.dumps(rep, indent=2, sort_keys=True))
    if args.json:
        with open(args.json, "w") as f:
            json.dump(reports if len(reports) > 1 else reports[0], f,
                      indent=2, sort_keys=True)
            f.write("\n")
    return 0


if __name__ == "__main__":
    sys.exit(main())
