"""Fleet control plane: N concurrent training jobs on one shared topology.

Public surface:

* :class:`~repro.fleet.scheduler.JobSpec` /
  :class:`~repro.fleet.scheduler.FleetScheduler` — gang scheduling, pending
  queue, priorities, preemption donors;
* :class:`~repro.fleet.view.JobView` — a per-job ClusterSim-compatible lens
  over the shared :class:`~repro.sim.topology.Topology` (claim-arbitrated
  replacements);
* :class:`~repro.fleet.engine.FleetConfig` /
  :func:`~repro.fleet.engine.run_fleet` — the multi-job discrete-event
  engine (shared clock, shared spare pool, contended NAS bandwidth);
* :mod:`repro.fleet.presets` — named fleet scenarios
  (``python -m repro.fleet --list``).
"""
from .engine import FleetConfig, no_preemption, run_fleet  # noqa: F401
from .presets import PRESETS, preset_names, run_preset  # noqa: F401
from .scheduler import FleetScheduler, JobSpec  # noqa: F401
from .view import JobView  # noqa: F401

__all__ = ["FleetConfig", "FleetScheduler", "JobSpec", "JobView",
           "PRESETS", "no_preemption", "preset_names", "run_fleet",
           "run_preset"]
