"""Fleet scheduler: gang scheduling, pending queue, priorities, preemption.

One :class:`FleetScheduler` owns admission onto a shared multi-job
:class:`~repro.sim.topology.Topology` (built with ``auto_assign=False``):

* **gang scheduling** — a job is admitted only when its *whole* node request
  can be claimed at once (all-or-nothing; partial claims are rolled back);
* **pending queue** — jobs that don't fit wait, ordered by priority then
  submission order, and are re-considered whenever capacity frees up
  (job completion, repairs landing);
* **preemption donors** — when a high-priority job's recovery finds the
  shared spare pool dry, the scheduler *names* the lowest-priority running
  job that can be elastically shrunk to donate a machine. Whether to take
  that rung at all is not decided here: the shared
  :class:`repro.recovery.RecoveryPlanner` owns the claim-vs-preempt-vs-
  shrink-vs-wait decision; this scheduler is pure mechanism.

The scheduler only moves leases; modelled time, recovery costs and fault
handling live in :mod:`repro.fleet.engine`.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Set

from repro.sim.soak import SoakPolicy, transom_policy
from repro.sim.topology import Topology

from .view import JobView


@dataclass(frozen=True)
class JobSpec:
    """One training job's request against the fleet."""
    name: str
    n_nodes: int
    priority: int = 0               # higher preempts lower
    ideal_hours: float = 6.0        # productive compute at full gang size
    min_nodes: int = 2              # elastic floor (== n_nodes: cannot shrink)
    ckpt_interval_s: float = 1800.0  # cadence, in productive seconds
    ckpt_bytes: float = 8e9         # checkpoint size -> NAS flow length
    step_time_s: float = 30.0       # one training step, for lost_steps
    submit_at_s: float = 0.0        # when the job enters the queue
    policy: SoakPolicy = field(default_factory=transom_policy)

    def __post_init__(self):
        if not (1 <= self.min_nodes <= self.n_nodes):
            raise ValueError(
                f"{self.name}: need 1 <= min_nodes <= n_nodes, "
                f"got {self.min_nodes}/{self.n_nodes}")


class FleetScheduler:
    """Claim-based admission + arbitration for N jobs on one topology."""

    def __init__(self, topology: Topology):
        assert not topology.assigned, \
            "fleet topology must be built with auto_assign=False"
        self.topo = topology
        self.pending: List[JobSpec] = []
        self.views: Dict[str, JobView] = {}
        self._submit_order: Dict[str, int] = {}
        self.stats = {"admitted": 0, "queued": 0, "claims_granted": 0,
                      "claims_denied": 0, "preemption_donations": 0}

    # -- admission -------------------------------------------------------- #
    def submit(self, spec: JobSpec) -> Optional[JobView]:
        """Queue a job and try to admit it. Returns its view if it was gang-
        scheduled immediately, else None (job waits in the pending queue)."""
        if spec.name in self.views or any(p.name == spec.name
                                          for p in self.pending):
            raise ValueError(f"duplicate job name {spec.name!r}")
        self._submit_order[spec.name] = len(self._submit_order)
        self.pending.append(spec)
        self.stats["queued"] += 1
        admitted = self.try_admit()
        return self.views.get(spec.name) if spec.name in \
            {s.name for s in admitted} else None

    def _queue_key(self, spec: JobSpec):
        return (-spec.priority, self._submit_order[spec.name])

    def submit_order(self, name: str) -> int:
        """Submission index of a job — the deterministic tie-break the
        engine's regrow pass shares with admission ordering."""
        return self._submit_order.get(name, 0)

    def try_admit(self) -> List[JobSpec]:
        """Admit every pending job whose full gang fits, highest priority
        first (all-or-nothing per job). Returns the admitted specs.

        Early-outs when the queue is empty: the fleet engine calls this on
        every control tick, and at steady state (all jobs admitted) the call
        must not pay the ``free_nodes()`` scan."""
        if not self.pending:
            return []
        admitted: List[JobSpec] = []
        for spec in sorted(self.pending, key=self._queue_key):
            free = self.topo.free_nodes()
            if len(free) < spec.n_nodes:
                continue
            granted = [self.topo.claim_specific(n, spec.name)
                       for n in free[:spec.n_nodes]]
            self.views[spec.name] = JobView(self.topo, spec.name, granted)
            self.pending.remove(spec)
            admitted.append(spec)
            self.stats["admitted"] += 1
        return admitted

    def complete(self, name: str) -> None:
        """A job finished: release its surviving leases back to the pool."""
        view = self.views.pop(name, None)
        if view is None:
            return
        for n in list(view.assigned):
            view.release(n)

    # -- replacement arbitration ------------------------------------------ #
    def claim_replacement(self, name: str, anti_affinity: Set[str],
                          avoid_domains=()) -> Optional[str]:
        """One job asks for a replacement machine from the shared pool."""
        view = self.views[name]
        got = view.schedule_replacement(anti_affinity, avoid_domains)
        self.stats["claims_granted" if got else "claims_denied"] += 1
        return got

    def find_donor(self, requester: JobSpec,
                   specs: Dict[str, JobSpec],
                   donatable: Set[str]) -> Optional[str]:
        """Lowest-priority running job (strictly below the requester) that
        can shrink by one node without crossing its elastic floor.
        ``donatable`` limits candidates to jobs the engine considers safely
        shrinkable right now (running/stalled, not mid-recovery)."""
        cands = []
        for jname, view in self.views.items():
            if jname == requester.name or jname not in donatable:
                continue
            spec = specs[jname]
            if spec.priority >= requester.priority:
                continue
            if len(view.assigned) - 1 < spec.min_nodes:
                continue
            cands.append((spec.priority, self._submit_order[jname], jname))
        if not cands:
            return None
        cands.sort()
        return cands[0][2]

    def donate(self, donor: str, requester: str) -> str:
        """Move one healthy machine from ``donor`` to ``requester``'s view.
        The lease is reassigned atomically — never observable as free."""
        donor_view, req_view = self.views[donor], self.views[requester]
        healthy = [n for n in donor_view.assigned
                   if self.topo.nodes[n].state.value == "healthy"]
        assert healthy, f"donor {donor!r} has no healthy node to give"
        node = healthy[-1]          # shed the highest-rank machine
        donor_view.assigned.remove(node)
        # the donor's fabric view must not keep reading the donated
        # machine's health as one of its own ranks
        donor_view.rebind_ranks(donor_view.assigned)
        self.topo.reassign_lease(node, requester)
        req_view.assigned.append(node)
        self.stats["preemption_donations"] += 1
        return node
