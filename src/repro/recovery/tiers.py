"""The N-tier checkpoint hierarchy model (TierCheck-style).

A :class:`Tier` is one rung of the checkpoint ladder — device HBM, host
DRAM, the peer ring, a rack-local SSD burst buffer, the shared NAS, a cold
object store — with a modelled bandwidth, a capacity budget, a failure
domain it is correlated with, and a durability bit. A :class:`TierTable`
is the ordered hierarchy (hottest first) one engine run plans against.

This module is a dependency-free leaf on purpose: the planner
(`repro.recovery.planner`), the TCE store/engine (`repro.core.tce`) and
the simulators all import it, so it must not import any of them back.

Failure-domain semantics (who dies together):

=========  ==============================================================
``node``   lives on the victim machine itself (HBM arena, host DRAM) —
           gone the instant the node is, useless for evicted restores
``rack``   rack-scoped (the peer ring neighbourhood, the rack burst
           buffer) — a rack outage takes out BOTH peer and ssd copies
``site``   site-durable (NAS, cold store) — survives node/rack loss
=========  ==============================================================
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Iterable, Optional, Tuple

# canonical tier names (the grep-able vocabulary of plans and reports)
TIER_DEVICE = "device"
TIER_DRAM = "dram"
TIER_PEER = "peer"
TIER_SSD = "ssd"
TIER_NAS = "nas"
TIER_COLD = "cold"

# failure-domain labels
DOMAIN_NODE = "node"
DOMAIN_RACK = "rack"
DOMAIN_SITE = "site"

# paper §IV-C: 71.1 MB/s effective NAS bandwidth per rank (keep in sync
# with repro.core.tce.store.NAS_BW_PER_RANK — duplicated here so this
# module stays import-free)
_NAS_BW = 71.1e6


@dataclass(frozen=True)
class Tier:
    """One rung of the checkpoint hierarchy."""
    name: str
    read_bw: float                  # bytes/s a restore streams at
    write_bw: float                 # bytes/s a save/demotion streams at
    failure_domain: str             # node | rack | site
    durable: bool                   # survives process death on this node
    capacity_bytes: int = 0         # per-rank budget; 0 = unbounded
    shared: bool = False            # contended across jobs (arbiter-worthy)

    def read_s(self, nbytes: float) -> float:
        return nbytes / self.read_bw if self.read_bw > 0 else 0.0

    def write_s(self, nbytes: float) -> float:
        return nbytes / self.write_bw if self.write_bw > 0 else 0.0


class TierTable:
    """An ordered checkpoint hierarchy, hottest (fastest) tier first."""

    def __init__(self, tiers: Iterable[Tier]):
        self.tiers: Tuple[Tier, ...] = tuple(tiers)
        if not self.tiers:
            raise ValueError("a TierTable needs at least one tier")
        self._by_name: Dict[str, Tier] = {t.name: t for t in self.tiers}
        if len(self._by_name) != len(self.tiers):
            raise ValueError("duplicate tier names in TierTable")

    def names(self) -> Tuple[str, ...]:
        return tuple(t.name for t in self.tiers)

    def get(self, name: str) -> Tier:
        return self._by_name[name]

    def __contains__(self, name: str) -> bool:
        return name in self._by_name

    def correlated(self, domain: str) -> Tuple[str, ...]:
        """Tier names lost together when ``domain`` fails. A rack outage
        takes out the rack tiers AND the node tiers of its machines."""
        hit = {DOMAIN_NODE: (DOMAIN_NODE,),
               DOMAIN_RACK: (DOMAIN_NODE, DOMAIN_RACK),
               DOMAIN_SITE: (DOMAIN_NODE, DOMAIN_RACK, DOMAIN_SITE),
               }.get(domain, (domain,))
        return tuple(t.name for t in self.tiers if t.failure_domain in hit)

    def coldest(self) -> Tier:
        return self.tiers[-1]


def default_tiers(*, ssd_capacity_bytes: int = 0,
                  nas_capacity_bytes: int = 0) -> TierTable:
    """The full six-rung hierarchy (TierCheck's ladder on TRANSOM's
    numbers). Device/DRAM die with the node; the peer ring and the
    rack burst-buffer SSD die with the rack; NAS and the cold object
    store are site-durable. Capacities default to unbounded; pass
    per-rank byte budgets to exercise demotion."""
    return TierTable((
        Tier(TIER_DEVICE, 200e9, 200e9, DOMAIN_NODE, durable=False),
        Tier(TIER_DRAM, 10e9, 10e9, DOMAIN_NODE, durable=False),
        Tier(TIER_PEER, 100e9, 100e9, DOMAIN_RACK, durable=False),
        Tier(TIER_SSD, 2e9, 1.2e9, DOMAIN_RACK, durable=True,
             capacity_bytes=ssd_capacity_bytes),
        Tier(TIER_NAS, _NAS_BW, _NAS_BW, DOMAIN_SITE, durable=True,
             capacity_bytes=nas_capacity_bytes, shared=True),
        Tier(TIER_COLD, 20e6, 20e6, DOMAIN_SITE, durable=True, shared=True),
    ))


def three_leg_tiers() -> TierTable:
    """The legacy cache→ring-backup→NAS waterfall expressed as a
    TierTable — planning against it reproduces the historical
    ``choose_restore_source`` decisions verbatim."""
    full = default_tiers()
    return TierTable((full.get(TIER_DRAM), full.get(TIER_PEER),
                      full.get(TIER_NAS)))


# legacy restore-source names for each tier (what the decision logs and
# SoakPolicy cost tables call the legs of the 3-leg waterfall)
LEGACY_SOURCE_BY_TIER = {
    TIER_DEVICE: "cache",
    TIER_DRAM: "cache",
    TIER_PEER: "backup",
    TIER_SSD: "store_full",
    TIER_NAS: "store_full",
    TIER_COLD: "store_full",
}


def tiers_down_for(table: TierTable, *, node_lost: bool,
                   rack_lost: bool = False,
                   extra_down: Iterable[str] = ()) -> Tuple[str, ...]:
    """Convenience: tier names unavailable after an incident."""
    down = set(extra_down)
    if rack_lost:
        down.update(table.correlated(DOMAIN_RACK))
    elif node_lost:
        down.update(table.correlated(DOMAIN_NODE))
    return tuple(t for t in table.names() if t in down)
