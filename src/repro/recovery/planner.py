"""The shared, cost-aware recovery decision core.

``RecoveryPlanner.plan(incident, cluster) -> RecoveryPlan`` is the ONE place
the detect→triage→shrink-vs-wait→claim→rollback→warmup loop decides what to
do. It is pure and clock-agnostic: everything time-like arrives inside the
:class:`Incident` / :class:`ClusterState` snapshots, nothing here reads a
clock, touches a topology or draws randomness — which is what makes the
decision log deterministic and the planner testable as a golden decision
table.

Candidate actions (the decision table):

===================  ======================================================
``recover_in_place``  no node attributable; restart on the same machines
``claim_spare``       lease a healthy machine from the shared pool
``preempt_donor``     shrink a lower-priority job to free one machine
``shrink``            continue degraded on the survivors (reshard via store)
``wait_for_repair``   stall the recovery until cordoned hardware heals
``regrow``            shrunken job reclaims capacity when a repair lands
``give_up``           nothing else is feasible (job fails)
===================  ======================================================

Every candidate is scored by modelled lost-work + restart cost
(Unicron-style): the rollback the action forces, the restore leg it implies
through the TCE waterfall, and — for ``shrink``/``wait`` — the throughput
lost while degraded or stalled. The *policy* chooses among scored
candidates and is selectable at runtime (Chameleon-style):

* ``"transom"`` — the paper's escalation ladder: claim → preempt → shrink →
  wait; cost scores are logged but the ordering is fixed.
* ``"cost"`` — pure cost minimisation: feasible candidates sorted by score.
* ``"no_shrink"`` — conservative: never run degraded; wait for repairs.

Engines execute plans through :func:`repro.recovery.executor.fill_slots`
and keep only mechanism (claim-ledger leases, restore waterfall, FSM).
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Dict, Iterable, List, Optional, Tuple

from .tiers import (LEGACY_SOURCE_BY_TIER, TIER_DEVICE, TIER_DRAM,
                    TIER_PEER, TierTable, three_leg_tiers)

# -- decision / action names (also the grep-able vocabulary of the log) ---- #
RECOVER_IN_PLACE = "recover_in_place"
CLAIM_SPARE = "claim_spare"
PREEMPT_DONOR = "preempt_donor"
SHRINK = "shrink"
WAIT_FOR_REPAIR = "wait_for_repair"
REGROW = "regrow"
STAY_SHRUNK = "stay_shrunk"
GIVE_UP = "give_up"

PLANNER_POLICIES = ("transom", "cost", "no_shrink")

# below this attribution confidence the planner refuses eviction rungs and
# recovers in place instead (streaming-TEE incidents carry a confidence;
# incidents without one keep the pre-confidence decision table verbatim)
CONFIDENCE_FLOOR = 0.5

# restore sources (the TCE waterfall legs a plan can land on)
SRC_CACHE = "cache"
SRC_BACKUP = "backup"
SRC_STORE = "store_full"

# the classic cache→ring-backup→NAS waterfall as a TierTable; planning
# against it reproduces the historical decision table verbatim
_LEGACY_TABLE = three_leg_tiers()


@dataclass(frozen=True)
class Incident:
    """What happened: one detected anomaly plus its triage facts."""
    kind: str = "fault"               # fault | repair | preemption | retry
    t: float = 0.0                    # modelled seconds at planning time
    victims: Tuple[str, ...] = ()     # attributable bad nodes (empty: none)
    categories: Tuple[str, ...] = ()  # Table-I categories of the victims
    mid_recovery_join: bool = False   # joined an already-open transaction
    ring_adjacent: bool = False       # victims were ring-backup neighbours
    # streaming-TEE attribution confidence in [0, 1]; None = the incident
    # came from a hard signal (process death, hw check), not a detector
    confidence: Optional[float] = None


@dataclass(frozen=True)
class ClusterState:
    """The planner's read-only snapshot of one job's slice of the cluster."""
    n_assigned: int                   # healthy machines still leased
    n_target: int                     # gang size (full strength)
    min_nodes: int                    # elastic floor; >= n_target: no shrink
    free_supply: int = 0              # machines claimable right now
    donor_available: bool = False     # a lower-priority job could donate
    repair_eta_s: Optional[float] = None   # next cordoned repair, if any
    wait_allowed: bool = False        # engine can stall/park this recovery
    has_ring_backup: bool = True
    topology_changed: bool = False    # ring size differs from the checkpoint
    progress_at_risk_s: float = 0.0   # work since the last durable ckpt
    remaining_s: float = float("nan")  # productive work left (NaN: unknown)


@dataclass(frozen=True)
class CostModel:
    """Modelled seconds per recovery phase — the engine's policy costs in
    the planner's vocabulary (one constructor per engine cost type)."""
    error_check_s: float = 90.0
    evict_reschedule_s: float = 360.0
    inplace_restart_s: float = 120.0
    warmup_s: float = 60.0
    restore_cache_s: float = 10.0
    restore_backup_s: float = 16.0
    restore_store_s: float = 255.0
    # N-tier hierarchy legs beyond the classic 3 (device HBM snapshot,
    # rack burst-buffer SSD, cold object store); tier-named sources from
    # choose_restore_plan resolve through these
    restore_device_s: float = 1.0
    restore_ssd_s: float = 30.0
    restore_cold_s: float = 900.0
    # a stalled recovery with no repair ETA is costed at this horizon
    unknown_repair_s: float = 24 * 3600.0
    # confidence-weighted terms (only consulted when the incident carries
    # an attribution confidence): evicting on a wrong attribution wastes a
    # reschedule + cordons a healthy machine; recovering in place on a
    # right one lets the fault recur
    misattribution_s: float = 900.0
    recurrence_s: float = 3600.0

    @classmethod
    def from_soak_policy(cls, pol) -> "CostModel":
        """From a :class:`repro.sim.soak.SoakPolicy`."""
        return cls(error_check_s=pol.error_check_s,
                   evict_reschedule_s=pol.evict_reschedule_s,
                   inplace_restart_s=pol.inplace_restart_s,
                   warmup_s=pol.warmup_s,
                   restore_cache_s=pol.restore_cache_s,
                   restore_backup_s=pol.restore_backup_s,
                   restore_store_s=pol.restore_store_s)

    @classmethod
    def from_phase_costs(cls, costs) -> "CostModel":
        """From the orchestrator's :class:`PhaseCosts` (no store leg there:
        the closed loop's resched restores are ring-backup pulls)."""
        return cls(error_check_s=costs.error_check,
                   evict_reschedule_s=costs.evict_reschedule,
                   inplace_restart_s=costs.inplace_restart,
                   warmup_s=costs.warmup,
                   restore_cache_s=costs.restore_from_cache,
                   restore_backup_s=costs.restore_from_backup,
                   restore_store_s=costs.restore_from_backup)

    def restore_s(self, source: str) -> float:
        """Modelled restore seconds for a waterfall leg — accepts both the
        legacy 3-leg names and the tier names of choose_restore_plan."""
        return {SRC_CACHE: self.restore_cache_s,
                SRC_BACKUP: self.restore_backup_s,
                SRC_STORE: self.restore_store_s,
                "device": self.restore_device_s,
                "dram": self.restore_cache_s,
                "peer": self.restore_backup_s,
                "ssd": self.restore_ssd_s,
                "nas": self.restore_store_s,
                "cold": self.restore_cold_s}[source]


@dataclass(frozen=True)
class Candidate:
    """One scored action from the decision table."""
    action: str
    cost_s: float                     # modelled lost-work + restart cost
    feasible: bool
    reason: str = ""

    def to_entry(self) -> dict:
        cost = None if math.isinf(self.cost_s) or math.isnan(self.cost_s) \
            else round(self.cost_s, 1)
        return {"action": self.action, "cost_s": cost,
                "feasible": self.feasible, "reason": self.reason}


@dataclass(frozen=True)
class RestorePlan:
    """A tier-ranked restore plan: every eligible tier hottest-first.

    ``source`` is the tier the restore should read from; the rest of
    ``tiers`` is the fallback order if that tier turns out empty, and is
    also what a speculative prefetch streams from while TOL is still
    electing/warming replacements."""
    tiers: Tuple[str, ...]
    source: str

    def legacy_source(self) -> str:
        """The 3-leg waterfall name of the chosen tier (decision-log and
        SoakPolicy cost-table vocabulary)."""
        return LEGACY_SOURCE_BY_TIER.get(self.source, SRC_STORE)


@dataclass(frozen=True)
class RecoveryPlan:
    """What the planner decided (policy) for the engine to execute
    (mechanism)."""
    decision: str                     # primary resolving action
    ladder: Tuple[str, ...]           # rung order for fill_slots
    restore_source: str               # expected TCE waterfall leg
    est_cost_s: float                 # score of the primary action
    candidates: Tuple[Candidate, ...]
    entry: dict                       # the JSON-able decision-log record


class DecisionLog:
    """Accumulates deterministic decision records for the run report."""

    def __init__(self):
        self.entries: List[dict] = []
        self.counts: Dict[str, int] = {}

    def record(self, entry: dict) -> None:
        self.entries.append(entry)
        d = entry["decision"]
        self.counts[d] = self.counts.get(d, 0) + 1

    def to_report(self, cap: int = 50) -> dict:
        """JSON-able summary: full counts, log capped deterministically."""
        return {"n": len(self.entries),
                "by_decision": dict(sorted(self.counts.items())),
                "log": self.entries[:cap]}


class RecoveryPlanner:
    """The one recovery brain shared by orchestrator, soak and fleet."""

    def __init__(self, policy: str = "transom",
                 costs: Optional[CostModel] = None,
                 log: Optional[DecisionLog] = None):
        if policy not in PLANNER_POLICIES:
            raise ValueError(f"unknown planner policy {policy!r}; "
                             f"have: {', '.join(PLANNER_POLICIES)}")
        self.policy = policy
        self.costs = costs or CostModel()
        self.log = log or DecisionLog()

    # -- restore-source decision (shared by all engines) ----------------- #
    @staticmethod
    def choose_restore_plan(table: TierTable, *, inplace: bool,
                            escalated: bool, has_ring_backup: bool = True,
                            down: Iterable[str] = ()) -> RestorePlan:
        """Rank the hierarchy's tiers for one restore, hottest first.

        Eligibility per tier (top to bottom of ``table``):

        * a tier named in ``down`` (failed hardware, a brownout, a
          correlated rack loss) is skipped outright;
        * without a ring backup (the manual baseline keeps no volatile
          replicas at all) only durable site-domain tiers qualify;
        * durable tiers always qualify;
        * the peer ring survives the victim node but not an escalated
          transaction (ring-adjacent double death / resize);
        * node-volatile tiers (device HBM, host DRAM) need the process to
          restart *in place* on surviving hardware — and even then an
          escalated transaction invalidates them (ring resize reshards).

        If nothing qualifies the plan falls back to the coldest tier —
        the durable floor of the hierarchy is never unreachable.
        """
        down = set(down)
        ranked = []
        for t in table.tiers:
            if t.name in down:
                continue
            if not has_ring_backup:
                if t.durable and t.failure_domain == "site":
                    ranked.append(t.name)
                continue
            if t.durable:
                ranked.append(t.name)
            elif t.name == TIER_PEER:
                if not escalated:
                    ranked.append(t.name)
            elif t.name in (TIER_DEVICE, TIER_DRAM):
                if inplace and not escalated:
                    ranked.append(t.name)
        if not ranked:
            ranked = [table.coldest().name]
        return RestorePlan(tuple(ranked), ranked[0])

    @staticmethod
    def choose_restore_source(*, inplace: bool, escalated: bool,
                              has_ring_backup: bool = True) -> str:
        """Which TCE waterfall leg a recovery restores through.

        No ring backup (manual baseline): every restore hits the store. An
        escalated transaction — ring-adjacent double death, a fault joining
        mid-restore, or a changed ring size (shrink/grow/preemption
        reshard) — falls through to the full store read, even if it began
        as an in-place restart. Plain in-place restarts read the local
        cache; otherwise the ring backup serves the restore.

        This is the 3-leg view of :meth:`choose_restore_plan`: plan over
        the legacy dram→peer→nas table, map the winning tier back to its
        waterfall name. Engines that model only the classic waterfall keep
        calling this; tiered engines call ``choose_restore_plan`` with
        their own table.
        """
        plan = RecoveryPlanner.choose_restore_plan(
            _LEGACY_TABLE, inplace=inplace, escalated=escalated,
            has_ring_backup=has_ring_backup)
        return plan.legacy_source()

    # -- candidate scoring ------------------------------------------------ #
    def _candidates(self, inc: Incident, st: ClusterState,
                    costs: CostModel) -> List[Candidate]:
        missing = max(st.n_target - st.n_assigned, 0)
        escalated = (inc.mid_recovery_join or inc.ring_adjacent
                     or st.topology_changed)
        full_src = self.choose_restore_source(
            inplace=False, escalated=escalated,
            has_ring_backup=st.has_ring_backup)
        rollback = st.progress_at_risk_s
        restart = costs.evict_reschedule_s + costs.warmup_s
        horizon = st.repair_eta_s if st.repair_eta_s is not None \
            else costs.unknown_repair_s
        out: List[Candidate] = []

        if missing == 0:
            src = self.choose_restore_source(
                inplace=True, escalated=escalated,
                has_ring_backup=st.has_ring_backup)
            out.append(Candidate(
                RECOVER_IN_PLACE, costs.inplace_restart_s
                + costs.restore_s(src) + costs.warmup_s + rollback,
                True, "no machine lost"))
            return out

        # confidence-weighted terms (streaming-TEE incidents only): evicting
        # on a shaky attribution risks cordoning a healthy machine, while
        # restarting in place on a solid one lets the fault recur
        conf = inc.confidence
        evict_tax = (1.0 - conf) * costs.misattribution_s \
            if conf is not None else 0.0
        if conf is not None:
            src = self.choose_restore_source(
                inplace=True, escalated=escalated,
                has_ring_backup=st.has_ring_backup)
            out.append(Candidate(
                RECOVER_IN_PLACE, costs.inplace_restart_s
                + costs.restore_s(src) + costs.warmup_s + rollback
                + conf * costs.recurrence_s,
                True, f"attribution confidence {conf:.2f}"))

        out.append(Candidate(
            CLAIM_SPARE, restart + costs.restore_s(full_src) + rollback
            + evict_tax,
            st.free_supply > 0,
            f"supply {st.free_supply} for {missing} slot(s)"))
        # the donor pays its own forced reshard (rollback through the store)
        donor_penalty = (costs.evict_reschedule_s + costs.restore_store_s
                         + costs.warmup_s)
        out.append(Candidate(
            PREEMPT_DONOR, restart + costs.restore_s(full_src) + rollback
            + donor_penalty + evict_tax,
            st.donor_available, "donor shrinks by one machine"))
        # run degraded on the current survivors: pay a store reshard now,
        # the lost throughput until hardware heals, and the regrow reshard
        # this planner will itself take once the repair lands
        frac = missing / max(st.n_target, 1)
        can_shrink = (st.min_nodes < st.n_target
                      and st.n_assigned >= st.min_nodes)
        regrow_reshard = (costs.evict_reschedule_s + costs.restore_store_s
                          + costs.warmup_s)
        out.append(Candidate(
            SHRINK, costs.restore_store_s + costs.warmup_s + rollback
            + frac * horizon + regrow_reshard,
            can_shrink, f"floor {st.min_nodes}, degraded x{frac:.2f}"))
        can_wait = st.wait_allowed or st.repair_eta_s is not None
        out.append(Candidate(
            WAIT_FOR_REPAIR, horizon + restart
            + costs.restore_s(full_src) + rollback,
            can_wait,
            "repair eta known" if st.repair_eta_s is not None
            else ("recovery can stall" if st.wait_allowed else "")))
        out.append(Candidate(GIVE_UP, float("inf"), True, "last resort"))
        return out

    def _ladder(self, cands: List[Candidate],
                confidence: Optional[float] = None) -> Tuple[str, ...]:
        order = {c.action: i for i, c in enumerate(cands)}
        low_conf = confidence is not None and confidence < CONFIDENCE_FLOOR
        if low_conf:
            # too shaky to evict anybody: restart in place (or stall)
            feasible = [c for c in cands
                        if c.feasible and c.action not in
                        (CLAIM_SPARE, PREEMPT_DONOR, SHRINK, GIVE_UP)]
        else:
            feasible = [c for c in cands
                        if c.feasible and c.action not in (RECOVER_IN_PLACE,
                                                           GIVE_UP)]
        if self.policy == "no_shrink":
            feasible = [c for c in feasible if c.action != SHRINK]
        if self.policy == "cost":
            feasible.sort(key=lambda c: (c.cost_s, order[c.action]))
        return tuple(c.action for c in feasible)

    @staticmethod
    def _decision(ladder: Tuple[str, ...], st: ClusterState) -> str:
        """The first rung that fully resolves the open slots."""
        missing = max(st.n_target - st.n_assigned, 0)
        if missing == 0:
            return RECOVER_IN_PLACE
        if ladder and ladder[0] == RECOVER_IN_PLACE:
            return RECOVER_IN_PLACE     # low-confidence: no eviction
        for rung in ladder:
            if rung == CLAIM_SPARE and st.free_supply >= missing:
                return CLAIM_SPARE
            if rung == PREEMPT_DONOR:
                return PREEMPT_DONOR
            if rung in (SHRINK, WAIT_FOR_REPAIR):
                return rung
        return GIVE_UP

    # -- planning entrypoints --------------------------------------------- #
    def plan(self, incident: Incident, cluster: ClusterState, *,
             costs: Optional[CostModel] = None, job: Optional[str] = None,
             record: bool = True) -> RecoveryPlan:
        """Score the decision table for one incident and pick a plan."""
        cm = costs or self.costs
        cands = self._candidates(incident, cluster, cm)
        ladder = self._ladder(cands, incident.confidence)
        decision = self._decision(ladder, cluster)
        escalated = (incident.mid_recovery_join or incident.ring_adjacent
                     or cluster.topology_changed or decision == SHRINK)
        source = self.choose_restore_source(
            inplace=decision == RECOVER_IN_PLACE, escalated=escalated,
            has_ring_backup=cluster.has_ring_backup)
        by_action = {c.action: c for c in cands}
        primary = by_action.get(decision) \
            or Candidate(decision, float("inf"), True)
        entry = self._entry(incident, cluster, decision, source, cands, job)
        if record:
            self.log.record(entry)
        return RecoveryPlan(decision, ladder, source, primary.cost_s,
                            tuple(cands), entry)

    def plan_regrow(self, cluster: ClusterState, *, t: float = 0.0,
                    costs: Optional[CostModel] = None,
                    job: Optional[str] = None,
                    record: Optional[bool] = None) -> RecoveryPlan:
        """A repair landed (or capacity freed): should a shrunken job pay a
        reshard to regrow? Cost-aware: the rollback + store reshard must be
        cheaper than the throughput still being lost while degraded."""
        cm = costs or self.costs
        st = cluster
        missing = max(st.n_target - st.n_assigned, 0)
        n_after = min(st.n_assigned + st.free_supply, st.n_target)
        reshard = (st.progress_at_risk_s + cm.evict_reschedule_s
                   + cm.restore_store_s + cm.warmup_s)
        if missing == 0 or st.free_supply <= 0 or st.n_assigned <= 0:
            benefit, feasible = 0.0, False
        elif math.isnan(st.remaining_s):
            # remaining work unknown: degradation is open-ended, regrow
            benefit, feasible = float("inf"), True
        else:
            # wall-clock saved over the remaining work by running at
            # n_after/n_target instead of n_assigned/n_target speed
            benefit = st.remaining_s * (st.n_target / st.n_assigned
                                        - st.n_target / n_after)
            feasible = True
        regrow = feasible and benefit > reshard
        decision = REGROW if regrow else STAY_SHRUNK
        cands = (
            Candidate(REGROW, reshard, feasible,
                      f"+{n_after - st.n_assigned} node(s), saves "
                      + ("open-ended" if math.isinf(benefit)
                         else f"{benefit:.0f}s")),
            Candidate(STAY_SHRUNK,
                      0.0 if math.isinf(benefit) else benefit, True,
                      "keep running degraded"),
        )
        incident = Incident(kind="repair", t=t)
        entry = self._entry(incident, cluster, decision, SRC_STORE,
                            list(cands), job)
        if record if record is not None else regrow:
            self.log.record(entry)
        return RecoveryPlan(decision, (REGROW,) if regrow else (),
                            SRC_STORE,
                            reshard if regrow else 0.0, cands, entry)

    # -- log record -------------------------------------------------------- #
    @staticmethod
    def _entry(inc: Incident, st: ClusterState, decision: str, source: str,
               cands: List[Candidate], job: Optional[str]) -> dict:
        entry = {
            "t": round(inc.t, 3),
            "kind": inc.kind,
            "victims": sorted(inc.victims),
            "decision": decision,
            "restore_source": source,
            "n_assigned": st.n_assigned,
            "n_target": st.n_target,
            "free_supply": st.free_supply,
            "candidates": [c.to_entry() for c in cands],
        }
        if inc.confidence is not None:
            entry["confidence"] = round(inc.confidence, 3)
        if job is not None:
            entry["job"] = job
        return entry
