"""One recovery brain: the shared, cost-aware RecoveryPlanner.

TRANSOM's core claim (paper §IV-A) is that the *automatic* fault-tolerance
strategy — not ad-hoc per-engine logic — decides how a task recovers. This
package is that strategy, extracted into a pure, clock-agnostic decision
core used by all three engines:

* the closed-loop orchestrator (:mod:`repro.core.tol.orchestrator`),
* the time-triggered soak engine (:mod:`repro.sim.soak`),
* the multi-job fleet engine (:mod:`repro.fleet.engine`).

The planner owns the decision table — recover-in-place vs claim-spare vs
preempt-donor vs shrink vs wait-for-repair, plus regrow-on-repair — scores
candidate actions by modelled lost-work + restart cost (Unicron-style), and
emits a structured, deterministic decision log that lands in every
scenario/soak/fleet JSON report. Engines keep only mechanism: leases via the
Topology claim ledger, the TCE restore waterfall, FSM transitions.

The policy itself is selectable at runtime (Chameleon-style): ``"transom"``
(the paper's escalation ladder), ``"cost"`` (pure cost minimisation over the
same candidates) and ``"no_shrink"`` (never run degraded; wait for repairs).
"""
from .cadence import CADENCE_ADAPT, CadenceController  # noqa: F401
from .executor import RecoveryExecutor, fill_slots  # noqa: F401
from .planner import (CLAIM_SPARE, GIVE_UP, PLANNER_POLICIES,  # noqa: F401
                      PREEMPT_DONOR, RECOVER_IN_PLACE, REGROW, SHRINK,
                      SRC_BACKUP, SRC_CACHE, SRC_STORE, STAY_SHRUNK,
                      WAIT_FOR_REPAIR, Candidate, ClusterState, CostModel,
                      DecisionLog, Incident, RecoveryPlan, RecoveryPlanner,
                      RestorePlan)
from .tiers import (LEGACY_SOURCE_BY_TIER, TIER_COLD,  # noqa: F401
                    TIER_DEVICE, TIER_DRAM, TIER_NAS, TIER_PEER, TIER_SSD,
                    Tier, TierTable, default_tiers, three_leg_tiers,
                    tiers_down_for)

__all__ = [
    "Candidate", "ClusterState", "CostModel", "DecisionLog", "Incident",
    "RecoveryExecutor", "RecoveryPlan", "RecoveryPlanner", "RestorePlan",
    "fill_slots",
    "PLANNER_POLICIES", "RECOVER_IN_PLACE", "CLAIM_SPARE", "PREEMPT_DONOR",
    "SHRINK", "WAIT_FOR_REPAIR", "REGROW", "STAY_SHRUNK", "GIVE_UP",
    "SRC_CACHE", "SRC_BACKUP", "SRC_STORE",
    "Tier", "TierTable", "default_tiers", "three_leg_tiers",
    "tiers_down_for", "LEGACY_SOURCE_BY_TIER",
    "TIER_DEVICE", "TIER_DRAM", "TIER_PEER", "TIER_SSD", "TIER_NAS",
    "TIER_COLD",
    "CadenceController", "CADENCE_ADAPT",
]
