"""The small executor protocol between the planner and the engines.

The planner decides (policy); engines act (mechanism). ``fill_slots`` is
the shared control flow that walks a plan's escalation ladder over an
engine's mechanism callbacks until every open replacement slot is resolved:

* ``try_claim``   — lease one machine through the Topology claim ledger;
* ``try_preempt`` — ask the scheduler to name + shrink a donor job;
* ``do_shrink``   — commit to running degraded (the survivors reshard);
* ``do_wait``     — stall until repairs land. Returns ``True`` when the
  engine actually waited (blocking engines: retry the ladder), ``False``
  when it cannot wait (nothing repairing), or ``None`` when the engine
  *parks* the recovery instead of blocking (the fleet DES moves the job to
  its WAITING state and re-enters the ladder on the next repair event).

Because cluster state moves underneath a recovery (faults absorbed during
waits, repairs landing, other jobs claiming), ``fill_slots`` re-plans from
a fresh :class:`~repro.recovery.planner.ClusterState` snapshot on every
iteration; only decision *changes* are recorded, so the log stays small and
deterministic.
"""
from __future__ import annotations

from typing import Callable, Optional

from .planner import (CLAIM_SPARE, PREEMPT_DONOR, SHRINK, WAIT_FOR_REPAIR,
                      ClusterState, CostModel, Incident, RecoveryPlanner)

# terminal outcomes of one fill_slots run
FILLED = "filled"          # every slot replaced at full strength
SHRUNK = "shrunk"          # committed to running degraded
WAITING = "waiting"        # recovery parked until capacity appears
GAVE_UP = "gave_up"        # no feasible rung left


class RecoveryExecutor:
    """Mechanism callbacks for one engine's open recovery transaction."""

    def __init__(self, *, missing: Callable[[], int],
                 try_claim: Callable[[], bool],
                 try_preempt: Optional[Callable[[], bool]] = None,
                 do_shrink: Optional[Callable[[], None]] = None,
                 do_wait: Optional[Callable[[], Optional[bool]]] = None):
        self.missing = missing
        self.try_claim = try_claim
        self.try_preempt = try_preempt or (lambda: False)
        self.do_shrink = do_shrink or (lambda: None)
        self.do_wait = do_wait or (lambda: False)


def fill_slots(planner: RecoveryPlanner, incident: Incident,
               state_fn: Callable[[], ClusterState],
               executor: RecoveryExecutor, *,
               costs: Optional[CostModel] = None,
               job: Optional[str] = None, record: bool = True) -> str:
    """Resolve an open recovery's replacement slots down the planned ladder.

    Returns one of ``filled`` / ``shrunk`` / ``waiting`` / ``gave_up``.
    With ``record=False`` nothing is logged (event-driven engines re-enter
    the ladder on every tick while a recovery is parked; those no-op
    retries must not flood the decision log).
    """
    last_decision: Optional[str] = None
    claim_blocked = False   # a claim failed against a stale supply snapshot
    while executor.missing() > 0:
        plan = planner.plan(incident, state_fn(), costs=costs, job=job,
                            record=False)
        if record and plan.decision != last_decision:
            planner.log.record(plan.entry)
        last_decision = plan.decision
        acted = False
        for rung in plan.ladder:
            if rung == CLAIM_SPARE:
                if not claim_blocked and executor.try_claim():
                    acted = True
                    break
                claim_blocked = True
            elif rung == PREEMPT_DONOR:
                if executor.try_preempt():
                    acted = True
                    break
            elif rung == SHRINK:
                executor.do_shrink()
                return SHRUNK
            elif rung == WAIT_FOR_REPAIR:
                waited = executor.do_wait()
                if waited is None:
                    return WAITING
                if waited:
                    claim_blocked = False   # repairs may have refilled supply
                    acted = True
                    break
        if not acted:
            return GAVE_UP
    return FILLED
