"""Planner-driven checkpoint-cadence adaptation (Chameleon-style).

The :class:`CadenceController` watches the rollback cost of each recovery
(lost work + restore leg, in modelled seconds) and tightens the save
interval when recent rollbacks run hot against the run's own baseline —
then relaxes back toward the configured interval once rollbacks cool
down. Every adaptation is recorded into the planner's
:class:`~repro.recovery.planner.DecisionLog`, so the decision log shows
*why* the cadence moved (the observed costs) next to every other
recovery decision.

Deterministic by construction: pure arithmetic over the observed
sequence, no clock reads, no randomness.
"""
from __future__ import annotations

from typing import List, Optional

from .planner import DecisionLog

CADENCE_ADAPT = "cadence_adapt"


class CadenceController:
    """Windowed rollback-cost controller for one job's save interval."""

    def __init__(self, base_interval_s: float, *,
                 min_interval_s: Optional[float] = None,
                 window: int = 4, tighten_ratio: float = 1.5,
                 log: Optional[DecisionLog] = None):
        self.base_interval_s = float(base_interval_s)
        self.interval_s = float(base_interval_s)
        self.min_interval_s = (min_interval_s if min_interval_s is not None
                               else base_interval_s / 8.0)
        self.window = max(int(window), 2)
        self.tighten_ratio = tighten_ratio
        self.log = log
        self._costs: List[float] = []
        self._baseline: Optional[float] = None
        self.adaptions = 0

    def observe_incident(self, t: float, rollback_cost_s: float) -> float:
        """Feed one recovery's rollback cost; returns the (possibly
        adapted) save interval to use from now on."""
        self._costs.append(float(rollback_cost_s))
        if self._baseline is None:
            if len(self._costs) >= max(self.window // 2, 2):
                self._baseline = (sum(self._costs) / len(self._costs))
            return self.interval_s
        recent = self._costs[-self.window:]
        mean = sum(recent) / len(recent)
        old = self.interval_s
        if mean > self.tighten_ratio * self._baseline:
            self.interval_s = max(self.min_interval_s, self.interval_s * 0.5)
        elif mean < self._baseline:
            self.interval_s = min(self.base_interval_s,
                                  self.interval_s * 1.25)
        if self.interval_s != old:
            self.adaptions += 1
            if self.log is not None:
                self.log.record({
                    "t": round(t, 3),
                    "kind": "cadence",
                    "decision": CADENCE_ADAPT,
                    "interval_s": [round(old, 1), round(self.interval_s, 1)],
                    "recent_rollback_s": round(mean, 1),
                    "baseline_rollback_s": round(self._baseline, 1),
                })
        return self.interval_s

    def to_report(self) -> dict:
        return {"initial_s": round(self.base_interval_s, 1),
                "final_s": round(self.interval_s, 1),
                "adaptions": self.adaptions}
