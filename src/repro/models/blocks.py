"""Decoder/encoder block assembly with scan-over-layers stacks.

An architecture is decomposed into *segments*: maximal runs of layers whose
(mixer, mlp) pattern repeats with a fixed period. Each segment is one
``lax.scan`` over stacked parameters — this keeps the HLO size O(period), not
O(n_layers), which is what makes 61-layer 671B configs compile quickly.

  llama3 / olmo / yi / phi4 / qwen2-vl : 1 segment, period [( attn, dense)]
  olmoe                                : 1 segment, period [( attn, moe )]
  deepseek-v3                          : prefix 3x(mla, dense) + 58x(mla, moe)
  jamba                                : 4x period-8 [7x(ssm, ·) + 1x(attn, ·)], moe on odd
  mamba2                               : 1 segment, period [( ssm, none )]
  whisper                              : encoder segment + decoder segment (+cross)
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.parallel.sharding import constrain

from . import attention as attn_mod
from . import mla as mla_mod
from . import moe as moe_mod
from . import ssm as ssm_mod
from .config import ModelConfig
from .layers import apply_norm, mlp_params, norm_params, apply_mlp
from .params import ParamBuilder, stacked


@dataclass(frozen=True)
class LayerSpec:
    kind: str          # attn | mla | ssm
    mlp: str           # dense | moe | none
    cross: bool = False


@dataclass(frozen=True)
class Segment:
    name: str
    n_steps: int
    specs: Tuple[LayerSpec, ...]

    @property
    def n_layers(self) -> int:
        return self.n_steps * len(self.specs)


def layer_spec(cfg: ModelConfig, i: int, cross: bool = False) -> LayerSpec:
    kind = cfg.layer_kind(i)
    if kind == "attn" and cfg.mla is not None:
        kind = "mla"
    mlp = cfg.mlp_kind(i)
    if cfg.family == "ssm":
        mlp = "none"
    return LayerSpec(kind, mlp, cross)


def segments(cfg: ModelConfig, cross: bool = False) -> List[Segment]:
    specs = [layer_spec(cfg, i, cross) for i in range(cfg.n_layers)]
    segs: List[Segment] = []
    start = 0
    if cfg.moe is not None and cfg.moe.first_k_dense > 0:
        k = cfg.moe.first_k_dense
        assert all(s == specs[0] for s in specs[:k])
        segs.append(Segment("prefix", k, (specs[0],)))
        start = k
    rest = specs[start:]
    if rest:
        for p in range(1, len(rest) + 1):
            if len(rest) % p == 0 and all(rest[i] == rest[i % p] for i in range(len(rest))):
                segs.append(Segment("stack", len(rest) // p, tuple(rest[:p])))
                break
    return segs


# --------------------------------------------------------------------------- #
# Params
# --------------------------------------------------------------------------- #
def layer_params(pb: ParamBuilder, cfg: ModelConfig, spec: LayerSpec, name: str):
    with pb.scope(name):
        p: Dict[str, Any] = {"norm1": norm_params(pb, cfg, "norm1")}
        if spec.kind == "attn":
            p["mix"] = attn_mod.attn_params(pb, cfg, "attn")
        elif spec.kind == "mla":
            p["mix"] = mla_mod.mla_params(pb, cfg, "attn")
        else:
            p["mix"] = ssm_mod.ssm_params(pb, cfg, "ssm")
        if spec.cross:
            p["norm_c"] = norm_params(pb, cfg, "norm_c")
            p["cross"] = attn_mod.attn_params(pb, cfg, "cross")
        if spec.mlp != "none":
            p["norm2"] = norm_params(pb, cfg, "norm2")
            if spec.mlp == "moe":
                p["mlp"] = moe_mod.moe_params(pb, cfg, "moe")
            else:
                p["mlp"] = mlp_params(pb, cfg, name="mlp")
        return p


def segment_params(pb: ParamBuilder, cfg: ModelConfig, seg: Segment):
    def one(pb_):
        return {f"l{j}": layer_params(pb_, cfg, spec, f"l{j}")
                for j, spec in enumerate(seg.specs)}

    with pb.scope(seg.name):
        return stacked(pb, seg.n_steps, one)


# --------------------------------------------------------------------------- #
# Cache
# --------------------------------------------------------------------------- #
def layer_cache_spec(cfg: ModelConfig, spec: LayerSpec, batch: int, max_len: int,
                     enc_len: Optional[int]):
    """Returns dict of (shape, dtype, logical_axes) per cache leaf."""
    dt = jnp.dtype(cfg.compute_dtype)
    out: Dict[str, Tuple[tuple, Any, tuple]] = {}
    if spec.kind == "attn":
        kv = (batch, max_len, cfg.n_kv_heads, cfg.d_head)
        ax = ("batch", "cache_seq", "act_kv_heads", None)
        out["k"] = (kv, dt, ax)
        out["v"] = (kv, dt, ax)
    elif spec.kind == "mla":
        m = cfg.mla
        out["ckv"] = ((batch, max_len, m.kv_lora_rank), dt, ("batch", "cache_seq", None))
        out["kpe"] = ((batch, max_len, m.qk_rope_dim), dt, ("batch", "cache_seq", None))
    else:
        d_in, n_heads, conv_dim = ssm_mod.ssm_dims(cfg)
        s = cfg.ssm
        out["conv"] = ((batch, s.d_conv - 1, conv_dim), dt, ("batch", None, "act_mlp"))
        out["state"] = ((batch, n_heads, s.head_dim, s.d_state), jnp.float32,
                        ("batch", "state_heads", None, None))
    if spec.cross:
        assert enc_len is not None
        kv = (batch, enc_len, cfg.n_kv_heads, cfg.d_head)
        ax = ("batch", None, "act_kv_heads", None)
        out["ek"] = (kv, dt, ax)
        out["ev"] = (kv, dt, ax)
    return out


def cache_struct(cfg: ModelConfig, batch: int, max_len: int,
                 enc_len: Optional[int] = None, mode: str = "shape"):
    """Cache pytree ('shape' -> ShapeDtypeStruct, 'zeros' -> arrays,
    'axes' -> logical-axis tuples). Leading dim of every leaf = seg.n_steps."""
    tree: Dict[str, Any] = {}
    for seg in segments(cfg, cross=(cfg.family == "encdec")):
        seg_tree: Dict[str, Any] = {}
        for j, spec in enumerate(seg.specs):
            leaves = {}
            for k, (shape, dt, ax) in layer_cache_spec(cfg, spec, batch, max_len, enc_len).items():
                full = (seg.n_steps,) + shape
                if mode == "shape":
                    leaves[k] = jax.ShapeDtypeStruct(full, dt)
                elif mode == "zeros":
                    leaves[k] = jnp.zeros(full, dt)
                else:
                    leaves[k] = (None,) + ax
            seg_tree[f"l{j}"] = leaves
        tree[seg.name] = seg_tree
    return tree


# --------------------------------------------------------------------------- #
# Layer forward
# --------------------------------------------------------------------------- #
def layer_forward(p, x: jax.Array, cfg: ModelConfig, spec: LayerSpec,
                  *, mode: str, positions=None, pos=None, cache=None,
                  enc_out=None, mrope_sections=None, attn_impl: str = "xla"):
    """Returns (x, new_cache_leaves, aux_loss)."""
    aux = jnp.zeros((), jnp.float32)
    new_cache: Dict[str, jax.Array] = {}
    h = apply_norm(p["norm1"], x, cfg)

    if spec.kind == "attn":
        use_rope = cfg.pos_embedding == "rope"
        if mode == "decode":
            y, nk, nv = attn_mod.attention_decode(
                p["mix"], h, cfg, cache["k"], cache["v"], pos,
                mrope_sections=mrope_sections, use_rope=use_rope)
            new_cache.update(k=nk, v=nv)
        else:
            y, kv = attn_mod.attention_forward(
                p["mix"], h, cfg, positions, causal=True,
                mrope_sections=mrope_sections, use_rope=use_rope,
                attn_impl=attn_impl)
            if mode == "prefill":
                new_cache.update(kv)
    elif spec.kind == "mla":
        if mode == "decode":
            y, nckv, nkpe = mla_mod.mla_decode(
                p["mix"], h, cfg, cache["ckv"], cache["kpe"], pos)
            new_cache.update(ckv=nckv, kpe=nkpe)
        else:
            y, latent = mla_mod.mla_forward(p["mix"], h, cfg, positions)
            if mode == "prefill":
                new_cache.update(latent)
    else:  # ssm
        if mode == "decode":
            y, nconv, nstate = ssm_mod.ssm_decode(
                p["mix"], h, cfg, cache["conv"], cache["state"])
            new_cache.update(conv=nconv, state=nstate)
        else:
            y, st = ssm_mod.ssm_forward(p["mix"], h, cfg)
            if mode == "prefill":
                new_cache.update(st)
    x = x + y
    x = constrain(x, ("batch", "seq", "act_embed"))

    if spec.cross:
        hc = apply_norm(p["norm_c"], x, cfg)
        if mode == "decode":
            ekv = (cache["ek"], cache["ev"])
            new_cache.update(ek=cache["ek"], ev=cache["ev"])  # pass through
        else:
            ekv = attn_mod.project_enc_kv(p["cross"], enc_out, cfg)
            if mode == "prefill":
                new_cache.update(ek=ekv[0], ev=ekv[1])
        x = x + attn_mod.cross_attention_forward(p["cross"], hc, ekv, cfg)

    if spec.mlp != "none":
        h2 = apply_norm(p["norm2"], x, cfg)
        if spec.mlp == "moe":
            y2, a = moe_mod.moe_forward(p["mlp"], h2, cfg)
            aux = aux + a
        else:
            y2 = apply_mlp(p["mlp"], h2, cfg)
        x = x + y2
        x = constrain(x, ("batch", "seq", "act_embed"))
    return x, new_cache, aux


# --------------------------------------------------------------------------- #
# Segment forward (scan or unrolled)
# --------------------------------------------------------------------------- #
def segment_forward(params, x: jax.Array, cfg: ModelConfig, seg: Segment,
                    *, mode: str, cache=None, **kw):
    """Run one segment. Returns (x, new_cache_or_None, aux)."""

    def body(carry, xs):
        x_, aux_ = carry
        p_step, cache_step = xs
        new_caches = {}
        for j, spec in enumerate(seg.specs):
            c = cache_step[f"l{j}"] if cache_step is not None else None
            x_, nc, a = layer_forward(p_step[f"l{j}"], x_, cfg, spec,
                                      mode=mode, cache=c, **kw)
            new_caches[f"l{j}"] = nc
            aux_ = aux_ + a
        return (x_, aux_), new_caches

    aux0 = jnp.zeros((), jnp.float32)
    want_cache = mode in ("prefill", "decode")

    if cfg.scan_layers:
        if cfg.remat and cfg.remat_policy != "none" and mode == "train":
            policy = (jax.checkpoint_policies.checkpoint_dots
                      if cfg.remat_policy == "dots" else None)
            body_fn = jax.checkpoint(body, policy=policy)
        else:
            body_fn = body
        xs = (params, cache)

        def scan_body(carry, xs_):
            p_step = xs_[0]
            c_step = xs_[1] if cache is not None else None
            return body_fn(carry, (p_step, c_step))

        scan_xs = (params, cache) if cache is not None else (params,)
        (x, aux), ys = jax.lax.scan(scan_body, (x, aux0), scan_xs)
        new_cache = ys if want_cache else None
        return x, new_cache, aux

    # unrolled (reduced smoke configs)
    aux = aux0
    ys_list = []
    for i in range(seg.n_steps):
        p_i = jax.tree.map(lambda t: t[i], params)
        c_i = jax.tree.map(lambda t: t[i], cache) if cache is not None else None
        (x, aux), nc = body((x, aux), (p_i, c_i))
        ys_list.append(nc)
    new_cache = None
    if want_cache:
        new_cache = jax.tree.map(lambda *xs: jnp.stack(xs), *ys_list)
    return x, new_cache, aux
