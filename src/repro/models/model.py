"""Top-level language model: embed -> segments -> final norm -> logits.

Covers every assigned family:
  * decoder-only (dense / MoE / SSM / hybrid)            — train & serve
  * encoder-decoder (whisper backbone, stub frontend)    — train & serve
  * VLM (qwen2-vl backbone, stub vision tower, M-RoPE)   — train & serve
  * DeepSeek MTP head (depth 1) as an auxiliary loss

`Batch` contract (all arrays optional unless the family needs them):
  tokens         (b, s) int32        decoder token ids
  labels         (b, s) int32        next-token targets (-1 = masked)
  enc_embeds     (b, enc_len, d)     whisper stub frontend output
  vision_embeds  (b, n_vis, d)       qwen2-vl stub patch embeddings
  positions      (b, s) or (3, b, s) overrides default arange (M-RoPE)
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.parallel.sharding import constrain

from . import blocks
from .config import ModelConfig
from .layers import embed_tokens, embedding_params, lm_logits, norm_params, apply_norm
from .params import ParamBuilder


# --------------------------------------------------------------------------- #
# Params
# --------------------------------------------------------------------------- #
def model_params(pb: ParamBuilder, cfg: ModelConfig):
    p: Dict[str, Any] = {"tok": embedding_params(pb, cfg)}
    if cfg.family == "encdec":
        enc_cfg = cfg  # same width; encoder layers are bidirectional, no cross
        with pb.scope("encoder"):
            p["encoder"] = {
                "seg": blocks.segment_params(
                    pb, enc_cfg,
                    blocks.Segment("enc", cfg.encdec.n_enc_layers,
                                   (blocks.LayerSpec("attn", "dense", False),))),
                "norm_f": norm_params(pb, enc_cfg, "norm_f"),
            }
    with pb.scope("decoder"):
        p["segments"] = {
            seg.name: blocks.segment_params(pb, cfg, seg)
            for seg in blocks.segments(cfg, cross=(cfg.family == "encdec"))
        }
        p["norm_f"] = norm_params(pb, cfg, "norm_f")
    if cfg.mtp_depth > 0:
        with pb.scope("mtp"):
            spec = blocks.layer_spec(cfg, cfg.n_layers - 1)
            p["mtp"] = {
                "proj": pb.param("proj", (2 * cfg.d_model, cfg.d_model),
                                 ("embed", "embed")),
                "norm_h": norm_params(pb, cfg, "norm_h"),
                "norm_e": norm_params(pb, cfg, "norm_e"),
                "layer": blocks.layer_params(pb, cfg, spec, "layer"),
                "norm_f": norm_params(pb, cfg, "norm_f"),
            }
    return p


def init_params(cfg: ModelConfig, key: Optional[jax.Array] = None, mode: str = "init"):
    pb = ParamBuilder(mode, key=key, param_dtype=jnp.dtype(cfg.param_dtype))
    return model_params(pb, cfg)


def param_axes(cfg: ModelConfig):
    return init_params(cfg, mode="axes")


def param_shapes(cfg: ModelConfig):
    return init_params(cfg, mode="shape")


# --------------------------------------------------------------------------- #
# Positional helpers
# --------------------------------------------------------------------------- #
def _sinusoid(positions: jax.Array, d: int) -> jax.Array:
    half = d // 2
    freq = jnp.exp(-jnp.log(10000.0) * jnp.arange(half, dtype=jnp.float32) / max(half - 1, 1))
    ang = positions.astype(jnp.float32)[..., None] * freq
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


def _default_positions(cfg: ModelConfig, batch: Dict[str, jax.Array]) -> jax.Array:
    if "positions" in batch:
        return batch["positions"]
    tokens = batch["tokens"]
    pos = jnp.broadcast_to(jnp.arange(tokens.shape[1])[None], tokens.shape)
    if cfg.vlm is not None:
        return jnp.broadcast_to(pos[None], (3,) + tokens.shape)   # M-RoPE (t,h,w)
    return pos


def _embed_inputs(params, cfg: ModelConfig, batch: Dict[str, jax.Array]) -> jax.Array:
    x = embed_tokens(params["tok"], batch["tokens"], cfg)
    if cfg.vlm is not None and "vision_embeds" in batch:
        nv = batch["vision_embeds"].shape[1]
        x = x.at[:, :nv].set(batch["vision_embeds"].astype(x.dtype))
    if cfg.pos_embedding == "sinusoid":
        pos = jnp.arange(x.shape[1])[None]
        x = x + _sinusoid(pos, cfg.d_model).astype(x.dtype)
    return constrain(x, ("batch", "seq", "act_embed"))


def _run_encoder(params, cfg: ModelConfig, enc_embeds: jax.Array) -> jax.Array:
    enc = params["encoder"]
    x = enc_embeds.astype(jnp.dtype(cfg.compute_dtype))
    pos = jnp.broadcast_to(jnp.arange(x.shape[1])[None], x.shape[:2])
    if cfg.pos_embedding == "sinusoid":
        x = x + _sinusoid(pos[:1], cfg.d_model).astype(x.dtype)
    seg = blocks.Segment("enc", cfg.encdec.n_enc_layers,
                         (blocks.LayerSpec("attn", "dense", False),))
    # encoder is bidirectional: reuse segment_forward with causal disabled via
    # a dedicated mode would complicate the scan; instead run layers directly.
    def body(carry, p_step):
        x_, = carry
        from .layers import apply_norm as _an
        p_l = p_step["l0"]
        h = _an(p_l["norm1"], x_, cfg)
        from . import attention as am
        y, _ = am.attention_forward(p_l["mix"], h, cfg, pos, causal=False,
                                    use_rope=False)
        x_ = x_ + y
        h2 = _an(p_l["norm2"], x_, cfg)
        from .layers import apply_mlp as _mlp
        x_ = x_ + _mlp(p_l["mlp"], h2, cfg)
        return (x_,), None

    if cfg.scan_layers:
        (x,), _ = jax.lax.scan(body, (x,), enc["seg"])
    else:
        for i in range(cfg.encdec.n_enc_layers):
            (x,), _ = body((x,), jax.tree.map(lambda t: t[i], enc["seg"]))
    return apply_norm(enc["norm_f"], x, cfg)


# --------------------------------------------------------------------------- #
# Forward passes
# --------------------------------------------------------------------------- #
def _run_segments(params, cfg: ModelConfig, x: jax.Array, *, mode: str,
                  cache=None, positions=None, pos=None, enc_out=None,
                  attn_impl: str = "xla"):
    mrope = cfg.vlm.mrope_sections if cfg.vlm is not None else None
    new_cache: Dict[str, Any] = {}
    aux = jnp.zeros((), jnp.float32)
    for seg in blocks.segments(cfg, cross=(cfg.family == "encdec")):
        c = cache[seg.name] if cache is not None else None
        x, nc, a = blocks.segment_forward(
            params["segments"][seg.name], x, cfg, seg, mode=mode, cache=c,
            positions=positions, pos=pos, enc_out=enc_out,
            mrope_sections=mrope, attn_impl=attn_impl)
        aux = aux + a
        if nc is not None:
            new_cache[seg.name] = nc
    return x, (new_cache if new_cache else None), aux


def forward(params, cfg: ModelConfig, batch: Dict[str, jax.Array],
            mode: str = "train", attn_impl: str = "xla"):
    """Train / prefill forward. Returns (logits, cache_or_None, aux)."""
    positions = _default_positions(cfg, batch)
    x = _embed_inputs(params, cfg, batch)
    enc_out = None
    if cfg.family == "encdec":
        enc_out = _run_encoder(params, cfg, batch["enc_embeds"])
    x, cache, aux = _run_segments(params, cfg, x, mode=mode,
                                  positions=positions, enc_out=enc_out,
                                  attn_impl=attn_impl)
    x = apply_norm(params["norm_f"], x, cfg)
    logits = lm_logits(params["tok"], x, cfg)
    logits = constrain(logits, ("batch", "seq", "act_vocab"))
    return logits, cache, aux, x


def decode_step(params, cfg: ModelConfig, token: jax.Array, cache,
                pos: jax.Array, batch_extras: Optional[Dict[str, jax.Array]] = None):
    """One-token decode. token: (b,) int32; pos: (b,). Returns (logits, cache)."""
    x = embed_tokens(params["tok"], token[:, None], cfg)
    if cfg.pos_embedding == "sinusoid":
        x = x + _sinusoid(pos[:, None], cfg.d_model).astype(x.dtype)
    x, new_cache, _ = _run_segments(params, cfg, x, mode="decode",
                                    cache=cache, pos=pos)
    x = apply_norm(params["norm_f"], x, cfg)
    logits = lm_logits(params["tok"], x, cfg)[:, 0]
    return logits, new_cache


# --------------------------------------------------------------------------- #
# Loss
# --------------------------------------------------------------------------- #
def cross_entropy(logits: jax.Array, labels: jax.Array) -> jax.Array:
    """Mean CE over labels >= 0. logits: (b, s, v) any float; labels: (b, s).

    The label pick uses iota==label select-reduce (not take_along_axis) so the
    vocab dim can stay model-sharded — XLA partitions the reduction and psums
    scalars instead of all-gathering (b, s, v) fp32 logits.
    """
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    iota = jax.lax.broadcasted_iota(jnp.int32, logits.shape, logits.ndim - 1)
    picked = jnp.where(iota == labels[..., None], logits, 0.0)
    ll = jnp.sum(picked, axis=-1)
    mask = (labels >= 0).astype(jnp.float32)
    nll = (lse - ll) * mask
    return jnp.sum(nll) / jnp.maximum(jnp.sum(mask), 1.0)


def _mtp_loss(params, cfg: ModelConfig, h_final: jax.Array,
              batch: Dict[str, jax.Array], positions) -> jax.Array:
    """DeepSeek MTP (depth 1): predict token t+2 from h_t and emb(t+1)."""
    mtp = params["mtp"]
    tokens, labels = batch["tokens"], batch["labels"]
    dt = jnp.dtype(cfg.compute_dtype)
    # next-token embeddings: shift tokens left by one
    nxt = jnp.concatenate([tokens[:, 1:], tokens[:, -1:]], axis=1)
    e = embed_tokens(params["tok"], nxt, cfg)
    h = apply_norm(mtp["norm_h"], h_final, cfg)
    e = apply_norm(mtp["norm_e"], e, cfg)
    x = jnp.einsum("bsd,dk->bsk", jnp.concatenate([h, e], axis=-1).astype(dt),
                   mtp["proj"].astype(dt))
    spec = blocks.layer_spec(cfg, cfg.n_layers - 1)
    x, _, _ = blocks.layer_forward(mtp["layer"], x, cfg, spec, mode="train",
                                   positions=positions)
    x = apply_norm(mtp["norm_f"], x, cfg)
    logits = lm_logits(params["tok"], x, cfg)
    # labels shifted by one more step
    lbl2 = jnp.concatenate([labels[:, 1:], jnp.full_like(labels[:, -1:], -1)], axis=1)
    return cross_entropy(logits, lbl2)


def loss_fn(params, cfg: ModelConfig, batch: Dict[str, jax.Array],
            attn_impl: str = "xla") -> Tuple[jax.Array, Dict[str, jax.Array]]:
    logits, _, aux, h_final = forward(params, cfg, batch, mode="train",
                                      attn_impl=attn_impl)
    ce = cross_entropy(logits, batch["labels"])
    loss = ce
    metrics = {"ce": ce}
    if cfg.moe is not None and cfg.moe.n_experts > 0:
        loss = loss + cfg.moe.aux_loss_weight * aux
        metrics["aux"] = aux
    if cfg.mtp_depth > 0:
        positions = _default_positions(cfg, batch)
        mtp = _mtp_loss(params, cfg, h_final, batch, positions)
        loss = loss + 0.3 * mtp
        metrics["mtp"] = mtp
    metrics["loss"] = loss
    return loss, metrics
