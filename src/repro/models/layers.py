"""Shared layers: norms, RoPE (incl. M-RoPE / partial), MLPs, embeddings.

All functions are pure; parameters come from :class:`~repro.models.params.ParamBuilder`.
Logical axis names used here (mapped to mesh axes in ``repro.parallel.sharding``):

  ``embed``    d_model dim of weights          (FSDP-sharded over data)
  ``heads``    q-heads*d_head fused dim        (TP over model)
  ``kv_heads`` kv-heads*d_head fused dim       (TP over model when divisible)
  ``mlp``      FFN hidden dim                  (TP over model)
  ``vocab``    vocabulary dim                  (TP over model)
  ``experts``  MoE expert dim                  (EP over model)
  ``layers``   stacked-scan leading dim        (never sharded)
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .params import ParamBuilder


# --------------------------------------------------------------------------- #
# Norms
# --------------------------------------------------------------------------- #
def norm_params(pb: ParamBuilder, cfg: ModelConfig, name: str):
    if cfg.norm == "nonparam_ln":
        return {}
    with pb.scope(name):
        p = {"scale": pb.param("scale", (cfg.d_model,), ("embed",), init="ones")}
        if cfg.norm == "layernorm":
            p["bias"] = pb.param("bias", (cfg.d_model,), ("embed",), init="zeros")
    return p


def apply_norm(p, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    if cfg.norm == "rmsnorm":
        x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + cfg.norm_eps)
        x = x * p["scale"].astype(jnp.float32)
    else:  # layernorm / nonparam_ln
        mu = jnp.mean(x, axis=-1, keepdims=True)
        var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
        x = (x - mu) * jax.lax.rsqrt(var + cfg.norm_eps)
        if cfg.norm == "layernorm":
            x = x * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)
    return x.astype(dt)


# --------------------------------------------------------------------------- #
# RoPE
# --------------------------------------------------------------------------- #
def rope_frequencies(dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, dim, 2, dtype=jnp.float32) / dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float,
               partial_factor: float = 1.0,
               mrope_sections: Optional[Tuple[int, int, int]] = None) -> jax.Array:
    """Rotate pairs (x[..., :d/2], x[..., d/2:]) — 'split-half' convention.

    x:         (..., seq, n_heads, d_head)
    positions: (batch, seq) int32, or (3, batch, seq) for M-RoPE.
    """
    d_head = x.shape[-1]
    rot = int(d_head * partial_factor)
    rot -= rot % 2
    x_rot, x_pass = x[..., :rot], x[..., rot:]
    inv = rope_frequencies(rot, theta)                          # (rot/2,)

    if mrope_sections is not None:
        # M-RoPE: frequency bands are assigned to (t, h, w) position streams.
        t_sec, h_sec, w_sec = mrope_sections
        assert t_sec + h_sec + w_sec == rot // 2
        sec_ids = jnp.concatenate([
            jnp.zeros((t_sec,), jnp.int32),
            jnp.ones((h_sec,), jnp.int32),
            jnp.full((w_sec,), 2, jnp.int32)])                   # (rot/2,)
        # positions: (3, batch, seq) -> per-band position (batch, seq, rot/2)
        pos = jnp.take_along_axis(
            positions.astype(jnp.float32).transpose(1, 2, 0),    # (b, s, 3)
            sec_ids[None, None, :], axis=-1)                     # (b, s, rot/2)
        angles = pos * inv[None, None, :]
    else:
        angles = positions.astype(jnp.float32)[..., None] * inv  # (b, s, rot/2)

    cos = jnp.cos(angles)[..., None, :]                          # (b, s, 1, rot/2)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x_rot.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return jnp.concatenate([out.astype(x.dtype), x_pass], axis=-1)


# --------------------------------------------------------------------------- #
# Dense MLP
# --------------------------------------------------------------------------- #
def mlp_params(pb: ParamBuilder, cfg: ModelConfig, d_ff: Optional[int] = None,
               name: str = "mlp"):
    d_ff = d_ff or cfg.d_ff
    with pb.scope(name):
        if cfg.activation == "swiglu":
            return {
                "wi": pb.param("wi", (cfg.d_model, d_ff), ("embed", "mlp")),
                "wg": pb.param("wg", (cfg.d_model, d_ff), ("embed", "mlp")),
                "wo": pb.param("wo", (d_ff, cfg.d_model), ("mlp", "embed")),
            }
        return {
            "wi": pb.param("wi", (cfg.d_model, d_ff), ("embed", "mlp")),
            "wo": pb.param("wo", (d_ff, cfg.d_model), ("mlp", "embed")),
        }


def apply_mlp(p, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    dt = jnp.dtype(cfg.compute_dtype)
    x = x.astype(dt)
    if "wg" in p:
        h = jnp.einsum("...d,df->...f", x, p["wi"].astype(dt))
        g = jnp.einsum("...d,df->...f", x, p["wg"].astype(dt))
        h = jax.nn.silu(g) * h
    else:
        h = jnp.einsum("...d,df->...f", x, p["wi"].astype(dt))
        h = jax.nn.gelu(h)
    return jnp.einsum("...f,fd->...d", h, p["wo"].astype(dt))


# --------------------------------------------------------------------------- #
# Embedding / head
# --------------------------------------------------------------------------- #
def embedding_params(pb: ParamBuilder, cfg: ModelConfig):
    with pb.scope("embed"):
        p = {"table": pb.param("table", (cfg.vocab_size, cfg.d_model),
                               ("vocab", "embed"), init="embed", scale=0.02)}
    if not cfg.tie_embeddings:
        with pb.scope("head"):
            p["head"] = pb.param("w", (cfg.d_model, cfg.vocab_size),
                                 ("embed", "vocab"))
    return p


def embed_tokens(p, tokens: jax.Array, cfg: ModelConfig) -> jax.Array:
    return p["table"].astype(jnp.dtype(cfg.compute_dtype))[tokens]


def lm_logits(p, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    dt = jnp.dtype(cfg.compute_dtype)
    w = p["table"].T if cfg.tie_embeddings else p["head"]
    return jnp.einsum("...d,dv->...v", x.astype(dt), w.astype(dt))
