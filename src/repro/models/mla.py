"""Multi-head Latent Attention (DeepSeek-V2/V3).

Training/prefill uses the reconstructing form (decompress K/V per token).
Decode uses the *absorbed* form: W_uk is folded into the query and W_uv into
the output so the KV cache stores only the ``kv_lora_rank + qk_rope_dim``
latent per token — the paper-faithful MLA memory win.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from .attention import chunked_attention, NEG_INF
from .config import ModelConfig
from .layers import apply_rope
from .params import ParamBuilder


def _rms(x: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return x.astype(dt)


def mla_params(pb: ParamBuilder, cfg: ModelConfig, name: str = "attn"):
    m = cfg.mla
    d, nh = cfg.d_model, cfg.n_heads
    qk = m.qk_nope_dim + m.qk_rope_dim
    with pb.scope(name):
        return {
            "w_dq": pb.param("w_dq", (d, m.q_lora_rank), ("embed", "lora")),
            "q_scale": pb.param("q_scale", (m.q_lora_rank,), ("lora",), init="ones"),
            "w_uq": pb.param("w_uq", (m.q_lora_rank, nh * qk), ("lora", "heads")),
            "w_dkv": pb.param("w_dkv", (d, m.kv_lora_rank), ("embed", "lora")),
            "kv_scale": pb.param("kv_scale", (m.kv_lora_rank,), ("lora",), init="ones"),
            "w_kr": pb.param("w_kr", (d, m.qk_rope_dim), ("embed", "lora")),
            "w_uk": pb.param("w_uk", (m.kv_lora_rank, nh * m.qk_nope_dim), ("lora", "heads")),
            "w_uv": pb.param("w_uv", (m.kv_lora_rank, nh * m.v_head_dim), ("lora", "heads")),
            "w_o": pb.param("w_o", (nh * m.v_head_dim, d), ("heads", "embed")),
        }


def _latents(p, x: jax.Array, cfg: ModelConfig, positions: jax.Array):
    """Compute (q_nope, q_pe, ckv, k_pe) — ckv/k_pe are what decode caches."""
    m = cfg.mla
    dt = jnp.dtype(cfg.compute_dtype)
    b, s, _ = x.shape
    nh = cfg.n_heads
    x = x.astype(dt)

    cq = _rms(jnp.einsum("bsd,dr->bsr", x, p["w_dq"].astype(dt))) * p["q_scale"].astype(dt)
    q = jnp.einsum("bsr,re->bse", cq, p["w_uq"].astype(dt))
    q = q.reshape(b, s, nh, m.qk_nope_dim + m.qk_rope_dim)
    q_nope, q_pe = q[..., :m.qk_nope_dim], q[..., m.qk_nope_dim:]
    q_pe = apply_rope(q_pe, positions, cfg.rope_theta)

    ckv = _rms(jnp.einsum("bsd,dr->bsr", x, p["w_dkv"].astype(dt))) * p["kv_scale"].astype(dt)
    k_pe = jnp.einsum("bsd,dr->bsr", x, p["w_kr"].astype(dt))
    k_pe = apply_rope(k_pe[:, :, None, :], positions, cfg.rope_theta)[:, :, 0, :]
    return q_nope, q_pe, ckv, k_pe


def mla_forward(p, x: jax.Array, cfg: ModelConfig, positions: jax.Array
                ) -> Tuple[jax.Array, dict]:
    """Training / prefill (reconstructing form). Returns (y, latent-cache)."""
    m = cfg.mla
    dt = jnp.dtype(cfg.compute_dtype)
    b, s, _ = x.shape
    nh = cfg.n_heads
    q_nope, q_pe, ckv, k_pe = _latents(p, x, cfg, positions)

    k_nope = jnp.einsum("bsr,re->bse", ckv, p["w_uk"].astype(dt)).reshape(b, s, nh, m.qk_nope_dim)
    v = jnp.einsum("bsr,re->bse", ckv, p["w_uv"].astype(dt)).reshape(b, s, nh, m.v_head_dim)
    q = jnp.concatenate([q_nope, q_pe], axis=-1)
    k = jnp.concatenate([k_nope, jnp.broadcast_to(k_pe[:, :, None, :], q_pe.shape)], axis=-1)

    o = chunked_attention(q, k, v, causal=True)
    y = jnp.einsum("bse,ed->bsd", o.reshape(b, s, -1), p["w_o"].astype(dt))
    return y, {"ckv": ckv, "kpe": k_pe}


def mla_decode(p, x: jax.Array, cfg: ModelConfig,
               cache_ckv: jax.Array, cache_kpe: jax.Array,
               pos: jax.Array) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Absorbed one-step decode.

    cache_ckv: (B, T, kv_lora_rank); cache_kpe: (B, T, qk_rope_dim); pos: (B,).
    """
    m = cfg.mla
    dt = jnp.dtype(cfg.compute_dtype)
    b = x.shape[0]
    nh = cfg.n_heads
    q_nope, q_pe, ckv, k_pe = _latents(p, x, cfg, pos[:, None])

    bidx = jnp.arange(b)
    cache_ckv = cache_ckv.at[bidx, pos].set(ckv[:, 0])
    cache_kpe = cache_kpe.at[bidx, pos].set(k_pe[:, 0])

    # absorb W_uk into q:  (b, nh, dn) x (kvr, nh, dn) -> (b, nh, kvr)
    w_uk = p["w_uk"].astype(dt).reshape(m.kv_lora_rank, nh, m.qk_nope_dim)
    q_abs = jnp.einsum("bnd,rnd->bnr", q_nope[:, 0], w_uk)

    scale = 1.0 / jnp.sqrt(jnp.array(m.qk_nope_dim + m.qk_rope_dim, jnp.float32))
    scores = (jnp.einsum("bnr,btr->bnt", q_abs, cache_ckv, preferred_element_type=jnp.float32)
              + jnp.einsum("bnr,btr->bnt", q_pe[:, 0], cache_kpe,
                           preferred_element_type=jnp.float32)) * scale
    t = cache_ckv.shape[1]
    mask = jnp.arange(t)[None, :] <= pos[:, None]
    scores = jnp.where(mask[:, None, :], scores, NEG_INF)
    w = jax.nn.softmax(scores, axis=-1).astype(dt)

    ctx = jnp.einsum("bnt,btr->bnr", w, cache_ckv)               # (b, nh, kvr)
    w_uv = p["w_uv"].astype(dt).reshape(m.kv_lora_rank, nh, m.v_head_dim)
    o = jnp.einsum("bnr,rnv->bnv", ctx, w_uv)                    # (b, nh, dv)
    y = jnp.einsum("be,ed->bd", o.reshape(b, -1), p["w_o"].astype(dt))
    return y[:, None, :], cache_ckv, cache_kpe
