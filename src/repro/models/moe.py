"""Mixture-of-experts with GShard-style capacity scatter dispatch.

Two implementations selected by ``MoEConfig.impl``:

* ``scatter`` (production) — tokens are bucketed into per-expert capacity
  slots via a cumulative-position scatter; the dispatched tensor is laid out
  ``(groups, experts, capacity, d_model)`` so *groups* shard over the data
  axes and *experts* shard over the model axis (EP). Under pjit the group→
  expert resharding lowers to the expected all-to-all. Overflow tokens are
  dropped (capacity factor 1.25 by default), faithful to GShard/Switch.
* ``dense`` (smoke tests) — every expert runs on every token, weighted by the
  (renormalised) top-k gate; exact, no drops, O(E) FLOPs.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.parallel.sharding import active, constrain

from .config import ModelConfig
from .layers import apply_mlp, mlp_params
from .params import ParamBuilder, stacked


def moe_params(pb: ParamBuilder, cfg: ModelConfig, name: str = "moe"):
    mo = cfg.moe
    d, ff = cfg.d_model, mo.d_ff_expert
    # expert weights: EP over 'model' on E, FSDP over 'data' on the FFN hidden
    # dim (f) — f-sharding makes the shard_map path's expert matmuls column-
    # then row-parallel with a single psum (see moe_shard_map.py). The router
    # is replicated (tiny, read by every device each layer).
    with pb.scope(name):
        p = {
            "router": pb.param("router", (cfg.d_model, mo.n_experts),
                               (None, None), scale=0.02),
            "wi": pb.param("wi", (mo.n_experts, d, ff), ("experts", None, "mlp_fsdp")),
            "wg": pb.param("wg", (mo.n_experts, d, ff), ("experts", None, "mlp_fsdp")),
            "wo": pb.param("wo", (mo.n_experts, ff, d), ("experts", "mlp_fsdp", None)),
        }
        if mo.n_shared:
            p["shared"] = mlp_params(pb, cfg, d_ff=mo.n_shared * mo.d_ff_shared,
                                     name="shared")
    return p


def _gate(p, x: jax.Array, cfg: ModelConfig):
    """Router: softmax over experts, top-k, renormalised. x: (..., d)."""
    mo = cfg.moe
    logits = jnp.einsum("...d,de->...e", x.astype(jnp.float32),
                        p["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    gate_w, expert_idx = jax.lax.top_k(probs, mo.top_k)           # (..., k)
    gate_w = gate_w / (jnp.sum(gate_w, axis=-1, keepdims=True) + 1e-9)
    return probs, gate_w, expert_idx


def _aux_loss(probs: jax.Array, expert_idx: jax.Array, n_experts: int) -> jax.Array:
    """Switch-style load-balance loss: E * sum_e f_e * p_e."""
    me = jnp.mean(probs.reshape(-1, n_experts), axis=0)
    counts = jnp.sum(jax.nn.one_hot(expert_idx.reshape(-1), n_experts,
                                    dtype=jnp.float32), axis=0)
    ce = counts / jnp.maximum(jnp.sum(counts), 1.0)
    return n_experts * jnp.sum(me * ce)


def _experts_apply(p, xs: jax.Array, cfg: ModelConfig) -> jax.Array:
    """Apply every expert to its slot block. xs: (..., E, C, d) -> same."""
    dt = jnp.dtype(cfg.compute_dtype)
    h = jnp.einsum("...ecd,edf->...ecf", xs, p["wi"].astype(dt))
    g = jnp.einsum("...ecd,edf->...ecf", xs, p["wg"].astype(dt))
    h = jax.nn.silu(g) * h
    return jnp.einsum("...ecf,efd->...ecd", h, p["wo"].astype(dt))


def _dispatch_one_group(x, gate_w, expert_idx, n_experts: int, capacity: int):
    """x: (g, d); gate_w/expert_idx: (g, k). Returns dispatched slots + indices."""
    g, k = expert_idx.shape
    flat_e = expert_idx.reshape(-1)                               # (g*k,) routing slots
    onehot = jax.nn.one_hot(flat_e, n_experts, dtype=jnp.int32)   # (g*k, E)
    # position of each routing slot within its expert queue
    pos = jnp.cumsum(onehot, axis=0) - 1                          # (g*k, E)
    slot_pos = jnp.sum(pos * onehot, axis=-1)                     # (g*k,)
    keep = slot_pos < capacity
    slot_pos = jnp.where(keep, slot_pos, capacity)                # overflow -> dropped row
    tok_idx = jnp.repeat(jnp.arange(g), k)
    disp = jnp.zeros((n_experts, capacity + 1, x.shape[-1]), x.dtype)
    disp = disp.at[flat_e, slot_pos].add(x[tok_idx] * keep[:, None].astype(x.dtype))
    return disp[:, :capacity], (flat_e, slot_pos, keep, tok_idx)


def _combine_one_group(out_slots, idx, gate_w, g: int):
    """out_slots: (E, C, d). Gather each routing slot back and weight-sum."""
    flat_e, slot_pos, keep, tok_idx = idx
    capacity = out_slots.shape[1]
    safe_pos = jnp.minimum(slot_pos, capacity - 1)
    rows = out_slots[flat_e, safe_pos]                            # (g*k, d)
    w = (gate_w.reshape(-1) * keep.astype(gate_w.dtype))[:, None]
    y = jnp.zeros((g, out_slots.shape[-1]), out_slots.dtype)
    return y.at[tok_idx].add(rows * w.astype(out_slots.dtype))


def moe_forward(p, x: jax.Array, cfg: ModelConfig) -> Tuple[jax.Array, jax.Array]:
    """x: (b, s, d) -> (y, aux_loss)."""
    mo = cfg.moe
    dt = jnp.dtype(cfg.compute_dtype)
    b, s, d = x.shape
    x = x.astype(dt)

    if mo.impl == "shard_map":
        ctx = active()
        if ctx is not None and "model" in ctx.mesh.axis_names:
            from .moe_shard_map import moe_forward_shard_map
            y, aux = moe_forward_shard_map(p, x, cfg)
            if mo.n_shared:
                y = y + apply_mlp(p["shared"], x, cfg)
            return y, aux
        # no mesh (CPU tests): fall through to the scatter path

    probs, gate_w, expert_idx = _gate(p, x, cfg)
    aux = _aux_loss(probs, expert_idx, mo.n_experts)

    if mo.impl == "dense":
        h = jnp.einsum("bsd,edf->bsef", x, p["wi"].astype(dt))
        g = jnp.einsum("bsd,edf->bsef", x, p["wg"].astype(dt))
        out_e = jnp.einsum("bsef,efd->bsed", jax.nn.silu(g) * h, p["wo"].astype(dt))
        mask = jax.nn.one_hot(expert_idx, mo.n_experts, dtype=jnp.float32)  # (b,s,k,E)
        w_full = jnp.einsum("bske,bsk->bse", mask, gate_w)
        y = jnp.einsum("bsed,bse->bsd", out_e, w_full.astype(dt))
    else:
        # group = one sequence-chunk of one batch row. Target ~2 groups per
        # device so the dispatch/combine phase shards over the WHOLE mesh
        # (data AND model axes); the dispatched tensor is then explicitly
        # constrained to the expert-parallel layout (groups over data, experts
        # over model) — without these constraints XLA SPMD replicates the
        # scatter across the model axis and emits multi-GB partial-sum
        # all-reduces per layer (observed: 9.3 TB/device on deepseek-v3).
        ctx = active()
        ndev = ctx.n_devices if ctx is not None else 1
        n_chunks = 1
        while (b * n_chunks * 2 <= 2 * ndev and s // (n_chunks * 2) >= 128
               and s % (n_chunks * 2) == 0):
            n_chunks *= 2
        g_len = s // n_chunks
        xg = x.reshape(b * n_chunks, g_len, d)
        xg = constrain(xg, ("moe_groups", None, None))
        gw = gate_w.reshape(b * n_chunks, g_len, -1)
        ei = expert_idx.reshape(b * n_chunks, g_len, -1)
        capacity = max(1, int(g_len * mo.top_k / mo.n_experts * mo.capacity_factor))

        def one(xi, gwi, eii):
            disp, idx = _dispatch_one_group(xi, gwi, eii, mo.n_experts, capacity)
            return disp, idx

        disp, idx = jax.vmap(one)(xg, gw, ei)                     # (G, E, C, d)
        disp = constrain(disp, ("moe_groups_dp", "moe_experts", None, None))
        out_slots = _experts_apply(p, disp, cfg)
        out_slots = constrain(out_slots,
                              ("moe_groups_dp", "moe_experts", None, None))
        y = jax.vmap(_combine_one_group, in_axes=(0, 0, 0, None))(
            out_slots, idx, gw, g_len)
        y = constrain(y, ("moe_groups", None, None))
        y = y.reshape(b, s, d)

    if mo.n_shared:
        y = y + apply_mlp(p["shared"], x, cfg)
    return y, aux
