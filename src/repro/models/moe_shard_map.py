"""Explicit-collective MoE (shard_map) — the hillclimbed expert-parallel path.

XLA SPMD cannot partition the capacity-scatter dispatch: it falls back to
"involuntary full rematerialization" (replicate + partial-sum all-reduce),
which measured 9.3 TB/chip/step of all-reduce wire on deepseek-v3 train_4k.
This path takes manual control of the collective schedule instead:

  per device (b_loc, s_loc, d) tokens        [batch over (pod,data), seq over model]
    local top-k gate + capacity scatter  ->  (E, C_loc, d)
    all_to_all over 'model'              ->  (E_loc, ep*C_loc, d)    [EP dispatch]
    expert FFN: wi/wg column-parallel over 'data' (f-sharded), wo row-parallel
      -> one psum over 'data'            ->  (E_loc, ep*C_loc, d)
    all_to_all back                      ->  (E, C_loc, d)
    local combine                        ->  (b_loc, s_loc, d)

Wire per layer per chip ~ 2 x tokens_loc*k*cf*d (dispatch+return a2a)
+ tokens_loc*k*cf*d (psum) — vs the scatter path's full-tensor all-reduces.
Token drops are per-(device, expert) capacity, the standard EP semantics.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.parallel.compat import shard_map
from repro.parallel.sharding import active

from .config import ModelConfig
from .moe import _aux_loss, _combine_one_group, _dispatch_one_group, _gate


def _local_moe(router, wi, wg, wo, x, *, cfg: ModelConfig, ep: int,
               dp_axes: Tuple[str, ...]):
    """Per-device body. x: (b_loc, s_loc, d); wi/wg: (E_loc, d, f_loc);
    wo: (E_loc, f_loc, d). Returns (y, aux)."""
    mo = cfg.moe
    dt = jnp.dtype(cfg.compute_dtype)
    b_loc, s_loc, d = x.shape
    g = b_loc * s_loc
    xl = x.reshape(g, d).astype(dt)

    probs, gate_w, expert_idx = _gate({"router": router}, xl, cfg)
    aux = _aux_loss(probs, expert_idx, mo.n_experts)
    aux = jax.lax.pmean(jax.lax.pmean(aux, "model"), dp_axes)

    capacity = max(1, int(g * mo.top_k / mo.n_experts * mo.capacity_factor))
    disp, idx = _dispatch_one_group(xl, gate_w, expert_idx,
                                    mo.n_experts, capacity)      # (E, C, d)

    # EP dispatch: experts go home to their shard
    disp = jax.lax.all_to_all(disp, "model", split_axis=0, concat_axis=1,
                              tiled=True)                        # (E_loc, ep*C, d)

    # ZeRO-3 weight gathering: expert FFN weights are *stored* f-sharded over
    # 'data'; gather them for the local matmuls (each data device holds
    # different tokens, so partial-f compute + psum would be wrong — the
    # transpose of this gather reduce-scatters the expert grads, i.e. proper
    # ZeRO semantics).
    if "data" in dp_axes or dp_axes == ("pod", "data"):
        wi = jax.lax.all_gather(wi, "data", axis=2, tiled=True)
        wg = jax.lax.all_gather(wg, "data", axis=2, tiled=True)
        wo = jax.lax.all_gather(wo, "data", axis=1, tiled=True)
    h = jnp.einsum("ecd,edf->ecf", disp, wi.astype(dt))
    gte = jnp.einsum("ecd,edf->ecf", disp, wg.astype(dt))
    h = jax.nn.silu(gte) * h
    out = jnp.einsum("ecf,efd->ecd", h, wo.astype(dt))

    out = jax.lax.all_to_all(out, "model", split_axis=1, concat_axis=0,
                             tiled=True)                         # (E, C, d)
    y = _combine_one_group(out, idx, gate_w, g)
    return y.reshape(b_loc, s_loc, d), aux


def moe_forward_shard_map(p, x: jax.Array, cfg: ModelConfig
                          ) -> Tuple[jax.Array, jax.Array]:
    """x: (b, s, d) -> (y, aux). Requires an active sharding context whose
    mesh has a 'model' axis; falls back to the caller otherwise."""
    ctx = active()
    mesh = ctx.mesh
    axis_names = set(mesh.axis_names)
    dp_axes = tuple(a for a in ("pod", "data") if a in axis_names)

    x_spec = P(dp_axes if dp_axes else None, "model", None)
    w_spec = P("model", None, "data" if "data" in axis_names else None)
    wo_spec = P("model", "data" if "data" in axis_names else None, None)

    fn = shard_map(
        functools.partial(_local_moe, cfg=cfg,
                          ep=mesh.shape["model"], dp_axes=dp_axes),
        mesh=mesh,
        in_specs=(P(), w_spec, w_spec, wo_spec, x_spec),
        out_specs=(x_spec, P()),
        check_vma=False)
    return fn(p["router"], p["wi"], p["wg"], p["wo"], x)
