"""Model configuration for the repro model zoo.

A single :class:`ModelConfig` dataclass describes every architecture family the
framework supports (dense GQA transformers, MLA, MoE, SSM/Mamba-2, hybrid
interleaves, encoder-decoder, VLM/audio backbones with stub frontends).

Every assigned architecture in ``repro.configs`` instantiates one of these, and
``reduced()`` derives the tiny smoke-test variant of the same family.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple


@dataclass(frozen=True)
class MoEConfig:
    """Mixture-of-experts settings (GShard-style capacity dispatch)."""

    n_experts: int = 0                 # routed experts; 0 = dense model
    top_k: int = 2
    d_ff_expert: int = 0               # hidden size of each routed expert
    n_shared: int = 0                  # always-on shared experts (DeepSeek)
    d_ff_shared: int = 0               # hidden size of the shared expert(s)
    first_k_dense: int = 0             # leading dense layers (DeepSeek: 3)
    every: int = 1                     # MoE replaces MLP every `every` layers
    offset: int = 0                    # first MoE layer index within a period
    capacity_factor: float = 1.25
    aux_loss_weight: float = 0.01
    # 'scatter'  — capacity-based scatter dispatch (production; EP-shardable)
    # 'dense'    — compute all experts, weight by gate (tiny smoke configs only)
    impl: str = "scatter"


@dataclass(frozen=True)
class MLAConfig:
    """DeepSeek multi-head latent attention."""

    q_lora_rank: int = 1536
    kv_lora_rank: int = 512
    qk_nope_dim: int = 128
    qk_rope_dim: int = 64
    v_head_dim: int = 128


@dataclass(frozen=True)
class SSMConfig:
    """Mamba-2 (SSD) settings."""

    d_state: int = 128
    d_conv: int = 4
    expand: int = 2
    head_dim: int = 64
    n_groups: int = 1
    chunk: int = 256                   # SSD chunk length


@dataclass(frozen=True)
class HybridConfig:
    """Attention/SSM interleave (Jamba)."""

    attn_period: int = 8               # one attention layer per period
    attn_offset: int = 4               # index of the attention layer in a period


@dataclass(frozen=True)
class EncDecConfig:
    """Encoder-decoder (Whisper backbone; conv frontend is a stub)."""

    n_enc_layers: int = 4
    enc_len: int = 1500                # precomputed frame embeddings (stub)


@dataclass(frozen=True)
class VLMConfig:
    """VLM backbone (Qwen2-VL); the vision tower is a stub."""

    n_vision_tokens: int = 1024        # precomputed patch embeddings per sample
    mrope_sections: Tuple[int, int, int] = (16, 24, 24)  # t/h/w rope sections


@dataclass(frozen=True)
class ModelConfig:
    name: str = "model"
    family: str = "dense"              # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int = 2
    d_model: int = 128
    n_heads: int = 2
    n_kv_heads: int = 2
    d_head: int = 64
    d_ff: int = 256
    vocab_size: int = 256

    norm: str = "rmsnorm"              # rmsnorm | layernorm | nonparam_ln
    norm_eps: float = 1e-5
    activation: str = "swiglu"         # swiglu | gelu
    use_bias: bool = False
    tie_embeddings: bool = False

    pos_embedding: str = "rope"         # rope | sinusoid (whisper)
    rope_theta: float = 10000.0
    partial_rotary_factor: float = 1.0  # phi-4-mini: 0.75
    mtp_depth: int = 0                  # DeepSeek multi-token-prediction depth

    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    ssm: Optional[SSMConfig] = None
    hybrid: Optional[HybridConfig] = None
    encdec: Optional[EncDecConfig] = None
    vlm: Optional[VLMConfig] = None

    # dtypes
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"

    # how many trailing layers stay un-scanned (0 = scan everything scannable)
    scan_layers: bool = True
    remat: bool = True
    remat_policy: str = "full"          # full | dots | none

    # ------------------------------------------------------------------ #
    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def is_quadratic(self) -> bool:
        """True when full O(L^2) attention dominates (long_500k is skipped)."""
        return self.family not in ("ssm", "hybrid")

    @property
    def has_decoder(self) -> bool:
        return True  # every assigned arch has a decode step (whisper is enc-dec)

    def layer_kind(self, i: int) -> str:
        """'attn' or 'ssm' for decoder layer i."""
        if self.family == "ssm":
            return "ssm"
        if self.family == "hybrid":
            assert self.hybrid is not None
            return "attn" if i % self.hybrid.attn_period == self.hybrid.attn_offset else "ssm"
        return "attn"

    def mlp_kind(self, i: int) -> str:
        """'dense' or 'moe' for decoder layer i."""
        if self.moe is None or self.moe.n_experts == 0:
            return "dense"
        if i < self.moe.first_k_dense:
            return "dense"
        return "moe" if (i % self.moe.every) == self.moe.offset else "dense"

    def n_params(self) -> int:
        """Analytic parameter count (embedding + decoder stack [+ encoder])."""
        d, v = self.d_model, self.vocab_size
        total = v * d                       # input embedding
        if not self.tie_embeddings:
            total += v * d                  # output head
        total += self._stack_params(self.n_layers, decoder=True)
        if self.family == "encdec":
            assert self.encdec is not None
            total += self._stack_params(self.encdec.n_enc_layers, decoder=False)
        if self.mtp_depth > 0:
            # per MTP depth: 1 extra layer + combine projection
            total += self.mtp_depth * (self._layer_params(self.n_layers - 1) + 2 * d * d)
        return total

    def n_active_params(self) -> int:
        """Active (per-token) parameter count — differs for MoE."""
        if self.moe is None or self.moe.n_experts == 0:
            return self.n_params()
        total = self.n_params()
        moe_layers = sum(1 for i in range(self.n_layers) if self.mlp_kind(i) == "moe")
        expert_p = self._ffn_params(self.moe.d_ff_expert)
        inactive = moe_layers * (self.moe.n_experts - self.moe.top_k) * expert_p
        return total - inactive

    # -- helpers -------------------------------------------------------- #
    def _ffn_params(self, d_ff: int) -> int:
        mult = 3 if self.activation == "swiglu" else 2
        return mult * self.d_model * d_ff

    def _attn_params(self) -> int:
        d = self.d_model
        if self.mla is not None:
            m = self.mla
            qk_dim = m.qk_nope_dim + m.qk_rope_dim
            p = d * m.q_lora_rank + m.q_lora_rank * self.n_heads * qk_dim
            p += d * (m.kv_lora_rank + m.qk_rope_dim)
            p += m.kv_lora_rank * self.n_heads * (m.qk_nope_dim + m.v_head_dim)
            p += self.n_heads * m.v_head_dim * d
            return p
        q = d * self.n_heads * self.d_head
        kv = 2 * d * self.n_kv_heads * self.d_head
        o = self.n_heads * self.d_head * d
        return q + kv + o

    def _ssm_params(self) -> int:
        assert self.ssm is not None
        s = self.ssm
        d_in = s.expand * self.d_model
        n_heads = d_in // s.head_dim
        conv_dim = d_in + 2 * s.n_groups * s.d_state
        p = self.d_model * (2 * d_in + 2 * s.n_groups * s.d_state + n_heads)  # in_proj
        p += conv_dim * s.d_conv                                             # conv
        p += n_heads * 2 + n_heads                                           # A, D, dt_bias
        p += d_in * self.d_model                                             # out_proj
        return p

    def _layer_params(self, i: int) -> int:
        mix = self._ssm_params() if self.layer_kind(i) == "ssm" else self._attn_params()
        if self.mlp_kind(i) == "moe":
            assert self.moe is not None
            ffn = self.moe.n_experts * self._ffn_params(self.moe.d_ff_expert)
            ffn += self.moe.n_shared * self._ffn_params(self.moe.d_ff_shared)
            ffn += self.d_model * self.moe.n_experts  # router
        else:
            ffn = self._ffn_params(self.d_ff)
        norms = 2 * self.d_model if self.norm != "nonparam_ln" else 0
        return mix + ffn + norms

    def _stack_params(self, n_layers: int, decoder: bool) -> int:
        total = sum(self._layer_params(i) for i in range(n_layers))
        if self.family == "encdec" and decoder:
            total += n_layers * (self._attn_params() + (self.d_model if self.norm != "nonparam_ln" else 0))
        return total

    def reduced(self) -> "ModelConfig":
        """Tiny same-family config for CPU smoke tests."""
        kw = dict(
            name=self.name + "-reduced",
            n_layers=min(self.n_layers, 4 if self.family in ("hybrid", "moe") else 2),
            d_model=64,
            n_heads=4,
            n_kv_heads=min(self.n_kv_heads, 2) if self.n_kv_heads < self.n_heads else 4,
            d_head=16,
            d_ff=128,
            vocab_size=128,
            scan_layers=False,
            remat=False,
        )
        if self.family == "hybrid":
            kw["n_layers"] = 4
            kw["hybrid"] = HybridConfig(attn_period=2, attn_offset=1)
        if self.moe is not None and self.moe.n_experts > 0:
            kw["moe"] = dataclasses.replace(
                self.moe, n_experts=4, top_k=2, d_ff_expert=64,
                d_ff_shared=64 if self.moe.n_shared else 0,
                first_k_dense=min(self.moe.first_k_dense, 1),
                impl="dense")
            kw["n_layers"] = 4 if self.moe.first_k_dense else kw["n_layers"]
        if self.mla is not None:
            kw["mla"] = MLAConfig(q_lora_rank=32, kv_lora_rank=16,
                                  qk_nope_dim=16, qk_rope_dim=8, v_head_dim=16)
        if self.ssm is not None:
            kw["ssm"] = dataclasses.replace(self.ssm, d_state=16, head_dim=16, chunk=32)
        if self.encdec is not None:
            kw["encdec"] = EncDecConfig(n_enc_layers=2, enc_len=32)
        if self.vlm is not None:
            kw["vlm"] = VLMConfig(n_vision_tokens=8, mrope_sections=(2, 3, 3))
        if self.mtp_depth:
            kw["mtp_depth"] = 1
        return dataclasses.replace(self, **kw)
