"""GQA attention with a memory-bounded chunked (online) XLA path and an
optional Pallas flash-attention path.

The chunked path processes query blocks against the full K/V with an exact
per-row softmax, bounding the live score buffer at ``q_block × T`` — this is
what lets 32k-token prefill lower within v5e HBM without a custom kernel, and
it is also the shape the Pallas kernel tiles.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .layers import apply_rope
from .params import ParamBuilder

NEG_INF = -1e30


# --------------------------------------------------------------------------- #
# Params
# --------------------------------------------------------------------------- #
def attn_params(pb: ParamBuilder, cfg: ModelConfig, name: str = "attn",
                cross: bool = False):
    d, h, kh, dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    with pb.scope(name):
        p = {
            "wq": pb.param("wq", (d, h * dh), ("embed", "heads")),
            "wk": pb.param("wk", (d, kh * dh), ("embed", "kv_heads")),
            "wv": pb.param("wv", (d, kh * dh), ("embed", "kv_heads")),
            "wo": pb.param("wo", (h * dh, d), ("heads", "embed")),
        }
        if cfg.use_bias:
            p["bq"] = pb.param("bq", (h * dh,), ("heads",), init="zeros")
            p["bk"] = pb.param("bk", (kh * dh,), ("kv_heads",), init="zeros")
            p["bv"] = pb.param("bv", (kh * dh,), ("kv_heads",), init="zeros")
            p["bo"] = pb.param("bo", (d,), ("embed",), init="zeros")
    return p


# --------------------------------------------------------------------------- #
# Core attention math
# --------------------------------------------------------------------------- #
def _pick_q_block(seq: int) -> int:
    for blk in (512, 256, 128, 64, 32, 16, 8, 4, 2, 1):
        if seq % blk == 0 and blk <= seq:
            return blk
    return 1


def chunked_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                      causal: bool, q_block: Optional[int] = None,
                      kv_len: Optional[jax.Array] = None) -> jax.Array:
    """Exact attention, scanned over query blocks.

    q: (B, S, H, D);  k, v: (B, T, KH, D) with H = KH * rep.
    kv_len: optional per-batch valid KV length (decode with a cache).
    """
    b, s, h, d = q.shape
    t, kh = k.shape[1], k.shape[2]
    rep = h // kh
    scale = 1.0 / jnp.sqrt(jnp.array(d, jnp.float32))
    q_block = q_block or _pick_q_block(s)
    n_blocks = s // q_block

    qb = q.reshape(b, n_blocks, q_block, kh, rep, d)
    t_idx = jnp.arange(t)

    def one_block(carry, q_i):
        # `start` comes from the loop carry (not a constant xs array) so XLA
        # cannot hoist + materialise the causal masks of all blocks at once.
        start = carry * q_block
        scores = jnp.einsum("bqkrd,btkd->bkrqt", q_i, k,
                            preferred_element_type=jnp.float32) * scale
        mask = None
        if causal:
            q_idx = start + jnp.arange(q_block)
            mask = q_idx[:, None] >= t_idx[None, :]
        if kv_len is not None:
            len_mask = t_idx[None, :] < kv_len[:, None]          # (b, t)
            len_mask = len_mask[:, None, None, None, :]
            scores = jnp.where(len_mask, scores, NEG_INF)
        if mask is not None:
            scores = jnp.where(mask[None, None, None], scores, NEG_INF)
        w = jax.nn.softmax(scores, axis=-1)
        o = jnp.einsum("bkrqt,btkd->bqkrd", w.astype(v.dtype), v)
        return carry + 1, o

    _, out = jax.lax.scan(one_block, jnp.zeros((), jnp.int32),
                          jnp.moveaxis(qb, 1, 0))
    out = jnp.moveaxis(out, 0, 1).reshape(b, s, h, v.shape[-1])
    return out


def decode_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                     pos: jax.Array) -> jax.Array:
    """Single-step decode. q: (B, 1, H, D); k, v: (B, T, KH, D); pos: (B,)."""
    b, _, h, d = q.shape
    t, kh = k.shape[1], k.shape[2]
    rep = h // kh
    scale = 1.0 / jnp.sqrt(jnp.array(d, jnp.float32))
    qh = q.reshape(b, kh, rep, d)
    scores = jnp.einsum("bkrd,btkd->bkrt", qh, k,
                        preferred_element_type=jnp.float32) * scale
    mask = jnp.arange(t)[None, :] <= pos[:, None]                # (b, t)
    scores = jnp.where(mask[:, None, None, :], scores, NEG_INF)
    w = jax.nn.softmax(scores, axis=-1)
    o = jnp.einsum("bkrt,btkd->bkrd", w.astype(v.dtype), v)
    return o.reshape(b, 1, h, d)


# --------------------------------------------------------------------------- #
# Full module forward
# --------------------------------------------------------------------------- #
def _project_qkv(p, x: jax.Array, cfg: ModelConfig):
    dt = jnp.dtype(cfg.compute_dtype)
    b, s, _ = x.shape
    h, kh, dh = cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    x = x.astype(dt)
    q = jnp.einsum("bsd,de->bse", x, p["wq"].astype(dt))
    k = jnp.einsum("bsd,de->bse", x, p["wk"].astype(dt))
    v = jnp.einsum("bsd,de->bse", x, p["wv"].astype(dt))
    if cfg.use_bias:
        q, k, v = q + p["bq"].astype(dt), k + p["bk"].astype(dt), v + p["bv"].astype(dt)
    return (q.reshape(b, s, h, dh), k.reshape(b, s, kh, dh), v.reshape(b, s, kh, dh))


def _out_proj(p, o: jax.Array, cfg: ModelConfig) -> jax.Array:
    dt = jnp.dtype(cfg.compute_dtype)
    b, s = o.shape[:2]
    y = jnp.einsum("bse,ed->bsd", o.reshape(b, s, -1).astype(dt), p["wo"].astype(dt))
    if cfg.use_bias:
        y = y + p["bo"].astype(dt)
    return y


def attention_forward(p, x: jax.Array, cfg: ModelConfig,
                      positions: jax.Array,
                      causal: bool = True,
                      mrope_sections=None,
                      use_rope: bool = True,
                      attn_impl: str = "xla") -> Tuple[jax.Array, dict]:
    """Training / prefill forward. Returns (y, kv) — kv feeds the cache."""
    q, k, v = _project_qkv(p, x, cfg)
    if use_rope:
        q = apply_rope(q, positions, cfg.rope_theta, cfg.partial_rotary_factor, mrope_sections)
        k = apply_rope(k, positions, cfg.rope_theta, cfg.partial_rotary_factor, mrope_sections)
    if attn_impl == "pallas":
        from repro.kernels.flash_attention import ops as fa_ops
        o = fa_ops.flash_attention(q, k, v, causal=causal)
    else:
        o = chunked_attention(q, k, v, causal=causal)
    return _out_proj(p, o, cfg), {"k": k, "v": v}


def attention_decode(p, x: jax.Array, cfg: ModelConfig,
                     cache_k: jax.Array, cache_v: jax.Array,
                     pos: jax.Array, mrope_sections=None,
                     use_rope: bool = True) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """One decode step. cache_k/v: (B, T, KH, D); pos: (B,) write index.

    Returns (y, new_cache_k, new_cache_v).
    """
    b = x.shape[0]
    q, k, v = _project_qkv(p, x, cfg)                            # s == 1
    if use_rope:
        pos2d = pos[:, None]                                     # (b, 1)
        if mrope_sections is not None:
            pos_m = jnp.broadcast_to(pos2d[None], (3, b, 1))
            q = apply_rope(q, pos_m, cfg.rope_theta, cfg.partial_rotary_factor, mrope_sections)
            k = apply_rope(k, pos_m, cfg.rope_theta, cfg.partial_rotary_factor, mrope_sections)
        else:
            q = apply_rope(q, pos2d, cfg.rope_theta, cfg.partial_rotary_factor)
            k = apply_rope(k, pos2d, cfg.rope_theta, cfg.partial_rotary_factor)
    # scatter the new token into the cache at `pos` (per-batch index)
    bidx = jnp.arange(b)
    cache_k = cache_k.at[bidx, pos].set(k[:, 0])
    cache_v = cache_v.at[bidx, pos].set(v[:, 0])
    o = decode_attention(q, cache_k, cache_v, pos)
    return _out_proj(p, o, cfg), cache_k, cache_v


def cross_attention_forward(p, x: jax.Array, enc_kv: Tuple[jax.Array, jax.Array],
                            cfg: ModelConfig) -> jax.Array:
    """Cross-attention against precomputed encoder K/V (no RoPE, not causal)."""
    dt = jnp.dtype(cfg.compute_dtype)
    b, s, _ = x.shape
    h, dh = cfg.n_heads, cfg.d_head
    q = jnp.einsum("bsd,de->bse", x.astype(dt), p["wq"].astype(dt))
    if cfg.use_bias:
        q = q + p["bq"].astype(dt)
    q = q.reshape(b, s, h, dh)
    k, v = enc_kv
    o = chunked_attention(q, k, v, causal=False)
    return _out_proj(p, o, cfg)


def project_enc_kv(p, enc_out: jax.Array, cfg: ModelConfig):
    """Precompute cross-attention K/V from encoder output."""
    dt = jnp.dtype(cfg.compute_dtype)
    b, t, _ = enc_out.shape
    kh, dh = cfg.n_kv_heads, cfg.d_head
    k = jnp.einsum("btd,de->bte", enc_out.astype(dt), p["wk"].astype(dt))
    v = jnp.einsum("btd,de->bte", enc_out.astype(dt), p["wv"].astype(dt))
    if cfg.use_bias:
        k, v = k + p["bk"].astype(dt), v + p["bv"].astype(dt)
    return k.reshape(b, t, kh, dh), v.reshape(b, t, kh, dh)
