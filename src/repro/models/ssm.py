"""Mamba-2 (state-space duality) block.

Training/prefill runs the chunked SSD algorithm: intra-chunk terms are dense
(c x c) matmuls that map onto the MXU; inter-chunk state is carried by a
``lax.scan`` — O(S) time, O(c^2) live memory. Decode is the O(1) recurrent
step. The Pallas kernel in ``repro.kernels.ssd_scan`` tiles the same chunk
structure; this module is its oracle via ``kernels/ssd_scan/ref.py``.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .params import ParamBuilder


# --------------------------------------------------------------------------- #
# Params
# --------------------------------------------------------------------------- #
def ssm_dims(cfg: ModelConfig):
    s = cfg.ssm
    d_in = s.expand * cfg.d_model
    n_heads = d_in // s.head_dim
    conv_dim = d_in + 2 * s.n_groups * s.d_state
    return d_in, n_heads, conv_dim


def ssm_params(pb: ParamBuilder, cfg: ModelConfig, name: str = "ssm"):
    s = cfg.ssm
    d = cfg.d_model
    d_in, n_heads, conv_dim = ssm_dims(cfg)
    with pb.scope(name):
        return {
            # order: [z (d_in), xBC (conv_dim), dt (n_heads)]
            "w_in": pb.param("w_in", (d, 2 * d_in + 2 * s.n_groups * s.d_state + n_heads),
                             ("embed", "heads")),
            "conv_w": pb.param("conv_w", (s.d_conv, conv_dim), (None, "heads")),
            "conv_b": pb.param("conv_b", (conv_dim,), ("heads",), init="zeros"),
            "A_log": pb.param("A_log", (n_heads,), (None,), init="zeros"),
            "D": pb.param("D", (n_heads,), (None,), init="ones"),
            "dt_bias": pb.param("dt_bias", (n_heads,), (None,), init="zeros"),
            "w_out": pb.param("w_out", (d_in, d), ("heads", "embed")),
        }


# --------------------------------------------------------------------------- #
# SSD chunked scan (oracle for the Pallas kernel)
# --------------------------------------------------------------------------- #
def _segsum(x: jax.Array) -> jax.Array:
    """x: (..., c) -> (..., c, c); out[i, j] = sum_{k=j+1..i} x_k, -inf above diag."""
    c = x.shape[-1]
    cs = jnp.cumsum(x, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((c, c), bool))
    return jnp.where(mask, diff, -jnp.inf)


def ssd_chunked(x: jax.Array, dt: jax.Array, A: jax.Array,
                B: jax.Array, C: jax.Array, chunk: int,
                init_state: Optional[jax.Array] = None
                ) -> Tuple[jax.Array, jax.Array]:
    """Chunked SSD. Shapes:
      x: (b, s, h, p)  dt: (b, s, h)  A: (h,)  B, C: (b, s, g, n); h = g*rep
    Returns (y: (b, s, h, p), final_state: (b, h, p, n)).
    """
    b, s, h, p = x.shape
    g, n = B.shape[2], B.shape[3]
    rep = h // g
    assert s % chunk == 0, (s, chunk)
    l = s // chunk

    f32 = jnp.float32
    dA = (dt.astype(f32) * A.astype(f32)).reshape(b, l, chunk, h)          # (b,l,c,h)
    xdt = (x.astype(f32) * dt.astype(f32)[..., None]).reshape(b, l, chunk, g, rep, p)
    Bc = B.astype(f32).reshape(b, l, chunk, g, n)
    Cc = C.astype(f32).reshape(b, l, chunk, g, n)

    cum = jnp.cumsum(dA, axis=2)                                           # (b,l,c,h)
    # intra-chunk: L[i,j] = exp(segsum)  per head
    L = jnp.exp(_segsum(jnp.moveaxis(dA, -1, 2)))                          # (b,l,h,c,c)
    L = L.reshape(b, l, g, rep, chunk, chunk)
    CB = jnp.einsum("blign,bljgn->blgij", Cc, Bc)                          # (b,l,g,c,c)
    M = CB[:, :, :, None] * L                                              # (b,l,g,r,c,c)
    y_intra = jnp.einsum("blgrij,bljgrp->bligrp", M, xdt)

    # per-chunk input states
    decay_states = jnp.exp(cum[:, :, -1:, :] - cum)                        # (b,l,c,h)
    ds = decay_states.reshape(b, l, chunk, g, rep)
    S = jnp.einsum("bljgn,bljgr,bljgrp->blgrpn", Bc, ds, xdt)              # (b,l,g,r,p,n)

    # inter-chunk recurrence
    chunk_decay = jnp.exp(cum[:, :, -1, :]).reshape(b, l, g, rep)          # (b,l,g,r)
    if init_state is None:
        h0 = jnp.zeros((b, g, rep, p, n), f32)
    else:
        h0 = init_state.astype(f32).reshape(b, g, rep, p, n)

    def step(carry, inp):
        dec, s_l = inp                                                     # (b,g,r), (b,g,r,p,n)
        h_in = carry
        h_out = h_in * dec[..., None, None] + s_l
        return h_out, h_in

    (h_final, h_ins) = jax.lax.scan(step, h0,
                                    (jnp.moveaxis(chunk_decay, 1, 0),
                                     jnp.moveaxis(S, 1, 0)))
    h_ins = jnp.moveaxis(h_ins, 0, 1)                                      # (b,l,g,r,p,n)

    state_decay = jnp.exp(cum).reshape(b, l, chunk, g, rep)                # (b,l,c,g,r)
    y_inter = jnp.einsum("blign,blgrpn,bligr->bligrp", Cc, h_ins, state_decay)

    y = (y_intra + y_inter).reshape(b, s, h, p)
    return y.astype(x.dtype), h_final.reshape(b, h, p, n)


def ssd_decode_step(state: jax.Array, x: jax.Array, dt: jax.Array,
                    A: jax.Array, B: jax.Array, C: jax.Array
                    ) -> Tuple[jax.Array, jax.Array]:
    """O(1) recurrent step.
      state: (b, h, p, n)  x: (b, h, p)  dt: (b, h)  A: (h,)  B, C: (b, g, n)
    Returns (y: (b, h, p), new_state).
    """
    b, h, p, n = state.shape
    g = B.shape[1]
    rep = h // g
    f32 = jnp.float32
    dA = jnp.exp(dt.astype(f32) * A.astype(f32))                           # (b,h)
    Bh = jnp.repeat(B.astype(f32), rep, axis=1)                            # (b,h,n)
    Ch = jnp.repeat(C.astype(f32), rep, axis=1)
    upd = (dt.astype(f32)[..., None, None]
           * x.astype(f32)[..., None] * Bh[:, :, None, :])                 # (b,h,p,n)
    new_state = state.astype(f32) * dA[..., None, None] + upd
    y = jnp.einsum("bhpn,bhn->bhp", new_state, Ch)
    return y.astype(x.dtype), new_state


# --------------------------------------------------------------------------- #
# Conv helpers
# --------------------------------------------------------------------------- #
def causal_conv(xBC: jax.Array, w: jax.Array, b: jax.Array,
                init_state: Optional[jax.Array] = None) -> Tuple[jax.Array, jax.Array]:
    """Depthwise causal conv. xBC: (b, s, c); w: (k, c). Returns (y, tail_state)."""
    k = w.shape[0]
    if init_state is None:
        pad = jnp.zeros((xBC.shape[0], k - 1, xBC.shape[2]), xBC.dtype)
    else:
        pad = init_state.astype(xBC.dtype)
    xp = jnp.concatenate([pad, xBC], axis=1)
    y = sum(xp[:, i:i + xBC.shape[1]] * w[i].astype(xBC.dtype) for i in range(k))
    y = jax.nn.silu(y + b.astype(xBC.dtype))
    tail = xp[:, -(k - 1):] if k > 1 else jnp.zeros((xBC.shape[0], 0, xBC.shape[2]), xBC.dtype)
    return y, tail


# --------------------------------------------------------------------------- #
# Full block forward
# --------------------------------------------------------------------------- #
def _split_proj(p, x: jax.Array, cfg: ModelConfig):
    s = cfg.ssm
    d_in, n_heads, conv_dim = ssm_dims(cfg)
    dt_ = jnp.dtype(cfg.compute_dtype)
    zxbcdt = jnp.einsum("bsd,de->bse", x.astype(dt_), p["w_in"].astype(dt_))
    z = zxbcdt[..., :d_in]
    xBC = zxbcdt[..., d_in:d_in + conv_dim]
    dt = zxbcdt[..., d_in + conv_dim:]
    return z, xBC, dt


def ssm_forward(p, x: jax.Array, cfg: ModelConfig,
                init_conv: Optional[jax.Array] = None,
                init_state: Optional[jax.Array] = None,
                use_pallas: bool = False) -> Tuple[jax.Array, dict]:
    """Training / prefill. Returns (y, {'conv': tail, 'state': final_state})."""
    s = cfg.ssm
    d_in, n_heads, conv_dim = ssm_dims(cfg)
    dtype = jnp.dtype(cfg.compute_dtype)
    b, seq, _ = x.shape

    z, xBC, dt = _split_proj(p, x, cfg)
    xBC, conv_tail = causal_conv(xBC, p["conv_w"], p["conv_b"], init_conv)
    xs = xBC[..., :d_in].reshape(b, seq, n_heads, s.head_dim)
    B = xBC[..., d_in:d_in + s.n_groups * s.d_state].reshape(b, seq, s.n_groups, s.d_state)
    C = xBC[..., d_in + s.n_groups * s.d_state:].reshape(b, seq, s.n_groups, s.d_state)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"].astype(jnp.float32))
    A = -jnp.exp(p["A_log"].astype(jnp.float32))

    if use_pallas:
        from repro.kernels.ssd_scan import ops as ssd_ops
        y, state = ssd_ops.ssd_scan(xs, dt, A, B, C, chunk=s.chunk, init_state=init_state)
    else:
        chunk = min(s.chunk, seq)
        y, state = ssd_chunked(xs, dt, A, B, C, chunk=chunk, init_state=init_state)
    y = y + xs * p["D"].astype(y.dtype)[None, None, :, None]
    y = y.reshape(b, seq, d_in) * jax.nn.silu(z)
    out = jnp.einsum("bse,ed->bsd", y.astype(dtype), p["w_out"].astype(dtype))
    return out, {"conv": conv_tail, "state": state}


def ssm_decode(p, x: jax.Array, cfg: ModelConfig,
               conv_state: jax.Array, ssm_state: jax.Array
               ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """One decode step. x: (b, 1, d). Returns (y, new_conv, new_ssm)."""
    s = cfg.ssm
    d_in, n_heads, conv_dim = ssm_dims(cfg)
    dtype = jnp.dtype(cfg.compute_dtype)
    b = x.shape[0]

    z, xBC, dt = _split_proj(p, x, cfg)
    window = jnp.concatenate([conv_state.astype(xBC.dtype), xBC], axis=1)  # (b, k, c)
    k = p["conv_w"].shape[0]
    y_conv = sum(window[:, i] * p["conv_w"][i].astype(xBC.dtype) for i in range(k))
    y_conv = jax.nn.silu(y_conv + p["conv_b"].astype(xBC.dtype))           # (b, c)
    new_conv = window[:, 1:]

    xs = y_conv[:, :d_in].reshape(b, n_heads, s.head_dim)
    B = y_conv[:, d_in:d_in + s.n_groups * s.d_state].reshape(b, s.n_groups, s.d_state)
    C = y_conv[:, d_in + s.n_groups * s.d_state:].reshape(b, s.n_groups, s.d_state)
    dt1 = jax.nn.softplus(dt[:, 0].astype(jnp.float32) + p["dt_bias"].astype(jnp.float32))
    A = -jnp.exp(p["A_log"].astype(jnp.float32))

    y, new_state = ssd_decode_step(ssm_state, xs, dt1, A, B, C)
    y = y + xs * p["D"].astype(y.dtype)[None, :, None]
    y = y.reshape(b, d_in) * jax.nn.silu(z[:, 0])
    out = jnp.einsum("be,ed->bd", y.astype(dtype), p["w_out"].astype(dtype))
    return out[:, None], new_conv, new_state
