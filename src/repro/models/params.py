"""Single-source-of-truth parameter construction.

Model ``init`` functions are written once against a :class:`ParamBuilder`; the
builder is then run in one of three modes:

* ``init``  — materialise ``jnp`` arrays (deterministic per-path RNG folding);
* ``axes``  — return the identically-structured tree of *logical axis* tuples
  used by ``repro.parallel.sharding`` to derive ``PartitionSpec``s;
* ``shape`` — return ``jax.ShapeDtypeStruct`` stand-ins (dry-run, no allocation).

Because all three trees come from the same traversal they can never drift.
"""
from __future__ import annotations

from typing import Callable, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

Axes = Tuple[Optional[str], ...]


def _path_key(root: jax.Array, path: str) -> jax.Array:
    # Stable per-path fold-in (path hash is deterministic across runs).
    h = np.uint32(int.from_bytes(path.encode(), "little", signed=False) % (2**31 - 1))
    return jax.random.fold_in(root, h)


class ParamBuilder:
    """Builds a nested-dict parameter tree in one of three modes."""

    def __init__(self, mode: str, key: Optional[jax.Array] = None,
                 param_dtype: jnp.dtype = jnp.float32):
        assert mode in ("init", "axes", "shape")
        self.mode = mode
        self.key = key
        self.param_dtype = param_dtype
        self._scope: list[str] = []

    # -- scoping -------------------------------------------------------- #
    def scope(self, name: str) -> "_Scope":
        return _Scope(self, name)

    def _path(self, name: str) -> str:
        return "/".join(self._scope + [name])

    # -- leaf ----------------------------------------------------------- #
    def param(self, name: str, shape: Sequence[int], axes: Axes,
              init: str = "normal", scale: float = 1.0,
              dtype: Optional[jnp.dtype] = None):
        shape = tuple(int(s) for s in shape)
        assert len(axes) == len(shape), f"{self._path(name)}: axes {axes} vs shape {shape}"
        dtype = dtype or self.param_dtype
        if self.mode == "axes":
            return axes
        if self.mode == "shape":
            return jax.ShapeDtypeStruct(shape, dtype)
        key = _path_key(self.key, self._path(name))
        if init == "normal":
            fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
            std = scale / np.sqrt(max(fan_in, 1))
            return (jax.random.normal(key, shape, jnp.float32) * std).astype(dtype)
        if init == "zeros":
            return jnp.zeros(shape, dtype)
        if init == "ones":
            return jnp.ones(shape, dtype)
        if init == "embed":
            return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)
        raise ValueError(f"unknown init {init!r}")


class _Scope:
    def __init__(self, pb: ParamBuilder, name: str):
        self.pb, self.name = pb, name

    def __enter__(self):
        self.pb._scope.append(self.name)
        return self.pb

    def __exit__(self, *exc):
        self.pb._scope.pop()
        return False


def stacked(pb: ParamBuilder, n: int, fn: Callable[[ParamBuilder], dict]) -> dict:
    """Build `n` stacked copies of a sub-tree (leading 'layers' axis) for scan.

    In 'init' mode each layer gets its own fold-in; leaves gain a leading dim.
    """
    if pb.mode in ("axes", "shape"):
        one = fn(pb)

        def _lift(leaf):
            if pb.mode == "axes":
                return ("layers",) + tuple(leaf)
            return jax.ShapeDtypeStruct((n,) + tuple(leaf.shape), leaf.dtype)

        return jax.tree.map(_lift, one, is_leaf=lambda x: isinstance(x, (tuple, jax.ShapeDtypeStruct)))

    layers = []
    base_scope = list(pb._scope)
    for i in range(n):
        pb._scope = base_scope + [f"layer{i}"]
        layers.append(fn(pb))
    pb._scope = base_scope
    return jax.tree.map(lambda *xs: jnp.stack(xs), *layers)
