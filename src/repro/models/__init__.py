from .config import (EncDecConfig, HybridConfig, MLAConfig, MoEConfig,
                     ModelConfig, SSMConfig, VLMConfig)
from .model import (cross_entropy, decode_step, forward, init_params, loss_fn,
                    param_axes, param_shapes)
from .blocks import cache_struct, segments

__all__ = [
    "ModelConfig", "MoEConfig", "MLAConfig", "SSMConfig", "HybridConfig",
    "EncDecConfig", "VLMConfig", "init_params", "param_axes", "param_shapes",
    "forward", "decode_step", "loss_fn", "cross_entropy", "cache_struct",
    "segments",
]
