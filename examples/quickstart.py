"""Quickstart: train a reduced llama3 with asynchronous TCE checkpoints,
kill the "job", and resume from the freshest recoverable checkpoint.

    PYTHONPATH=src python examples/quickstart.py
"""
import tempfile

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core.tce import DiskStore, TCEngine, TCEConfig
from repro.core.tce.engine import unflatten_like
from repro.data import SyntheticLMData
from repro.train import AdamConfig, TrainConfig, init_train_state, make_train_step


def main():
    cfg = get_config("llama3-8b").reduced()
    opt = AdamConfig(lr=1e-3, warmup_steps=5, decay_steps=60)
    print(f"model: {cfg.name} ({cfg.n_params():,} params)")

    state = init_train_state(cfg, opt, jax.random.key(0))
    data = SyntheticLMData(cfg.vocab_size, seq_len=64, global_batch=8)
    step_fn = jax.jit(make_train_step(cfg, opt, TrainConfig()), donate_argnums=(0,))

    ckpt_dir = tempfile.mkdtemp(prefix="transom_quickstart_")
    tce = TCEngine(TCEConfig(n_nodes=4), DiskStore(ckpt_dir))

    for step in range(30):
        batch = {k: jnp.asarray(v) for k, v in data.batch_at(step).items()}
        state, metrics = step_fn(state, batch)
        if (step + 1) % 10 == 0:
            h = tce.save(step + 1, state)     # async: training is not stalled
            print(f"step {step+1:3d}  loss={float(metrics['loss']):.4f}  "
                  f"[tce cache write: {h.cache_wall_s*1e3:.1f} ms]")

    # --- simulate a crash + resume ---------------------------------------- #
    print("\n-- job killed; new process restores --")
    tce.reconciler.quiesce(30)
    ck_step, flat = tce.restore()
    state2 = unflatten_like(state, flat)
    print(f"restored step {ck_step} from "
          f"{tce.stats['restore_sources']} (memory-first waterfall)")
    for step in range(ck_step, ck_step + 10):
        batch = {k: jnp.asarray(v) for k, v in data.batch_at(step).items()}
        state2, metrics = step_fn(state2, batch)
    print(f"resumed training to step {int(state2.step)}  "
          f"loss={float(metrics['loss']):.4f}")
    tce.close()


if __name__ == "__main__":
    main()
