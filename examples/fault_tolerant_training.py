"""End-to-end driver: LM pre-training under the full TRANSOM closed loop.

A real jax training run (llama3-family reduced config) is protected by
TOL (launcher FSM + error checks + anti-affinity reschedule), TEE (anomaly
detection + node attribution), and TCE (async in-memory checkpoints + ring
backup). Faults are injected mid-run: a GPU failure on one simulated node and
a network fault on another. Training recovers automatically and the final
loss trajectory is identical to an uninterrupted run.

    PYTHONPATH=src python examples/fault_tolerant_training.py           # ~2 min
    PYTHONPATH=src python examples/fault_tolerant_training.py --full    # ~100M params, 300 steps
"""
import argparse
import dataclasses

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core.tol import JobConfig
from repro.core.tol.cluster import NodeState
from repro.core.tol.orchestrator import SimulatedFault
from repro.data import SyntheticLMData
from repro.models import ModelConfig
from repro.sim.scenarios import build_substrate
from repro.train import AdamConfig, TrainConfig, init_train_state, make_train_step


def build_config(full: bool) -> ModelConfig:
    if full:
        # ~100M-param llama-style model
        return dataclasses.replace(
            get_config("llama3-8b"), name="llama-100m",
            n_layers=12, d_model=512, n_heads=8, n_kv_heads=8, d_head=64,
            d_ff=2048, vocab_size=32768, scan_layers=True, remat=False)
    return dataclasses.replace(
        get_config("llama3-8b").reduced(), name="llama-tiny",
        n_layers=4, d_model=128, n_heads=4, n_kv_heads=4, d_head=32,
        d_ff=512, vocab_size=2048)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--steps", type=int, default=0)
    args = ap.parse_args()

    cfg = build_config(args.full)
    steps = args.steps or (300 if args.full else 120)
    batch_size, seq = (8, 256) if args.full else (8, 64)
    print(f"model: {cfg.name} ({cfg.n_params():,} params), {steps} steps")

    opt = AdamConfig(lr=1e-3, warmup_steps=steps // 10, decay_steps=steps)
    data = SyntheticLMData(cfg.vocab_size, seq, batch_size, seed=0)
    state0 = init_train_state(cfg, opt, jax.random.key(0))
    inner = jax.jit(make_train_step(cfg, opt, TrainConfig()))
    losses = []

    def step_fn(state, step):
        batch = {k: jnp.asarray(v) for k, v in data.batch_at(step).items()}
        new_state, metrics = inner(state, batch)
        losses.append((step, float(metrics["loss"])))
        return new_state

    # --- TRANSOM stack on the unified simulation substrate ------------------ #
    # one SimClock + one Topology shared by TOL, TEE and TCE (repro.sim)
    print("building substrate (TEE fit on normal traces) ...")
    sub = build_substrate(n_nodes=4, n_spares=4, verbose=True)
    cluster, op = sub.topology, sub.operator
    assert sub.clock_identity_ok(), "subsystems must share one clock"

    faults = {steps // 3: ("node_hw", 1), 2 * steps // 3: ("network", 2)}
    fired = set()

    def fault_hook(step):
        if step in faults and step not in fired:
            fired.add(step)
            cat, rank = faults[step]
            node = op.launchers[rank].node
            cluster.nodes[node].state = NodeState.FAILED
            cluster.nodes[node].fail_category = cat
            print(f"\n*** injecting {cat} fault on rank {rank} ({node}) "
                  f"at step {step} ***")
            raise SimulatedFault(cat, rank)

    report, final_state = op.run_job(
        JobConfig(total_steps=steps, ckpt_every=max(steps // 12, 5),
                  n_sim_nodes=4),
        state0, step_fn, fault_hook=fault_hook)
    op.tce.close()

    print(f"\ncompleted={report.completed} steps={report.steps_done}")
    print(f"restarts: in-place={report.restarts_inplace} "
          f"rescheduled={report.restarts_resched} "
          f"evicted={report.evicted_nodes}")
    print(f"lost steps (recomputed): {report.lost_steps}")
    print(f"mean modeled restart: {report.mean_restart_s/60:.1f} min "
          f"(paper: ~12 min)")
    print(f"modeled cluster time: {sub.clock.seconds:.1f} s on one shared clock")
    print(f"anti-affinity registry: {sorted(sub.server.bad_nodes())}")
    first = [l for s, l in losses if s < 10]
    last = [l for s, l in losses[-10:]]
    print(f"loss: {sum(first)/len(first):.3f} (start) -> "
          f"{sum(last)/len(last):.3f} (end)")
    print("\nFSM history:")
    for t, s, r in report.state_history:
        print(f"  {s:>16s}  {r[:60]}")


if __name__ == "__main__":
    main()
