"""End-to-end demo: training survives SIGKILLs under the TRANSOM stack.

Drives the one Substrate API (``repro.substrate``) through the shared
recovery loop (``run_protected``): TOL's launcher FSM and error checks, TEE
anomaly attribution, planner-arbitrated replacement via the Topology claim
ledger, and TCE checkpoint restore. Faults are scripted ``KillSpec``s; on
the (default) process substrate each one SIGKILLs a live rank process
running the real trainer, on the sim substrate it fails a modelled node —
the driver cannot tell the difference, by design.

After the protected run, an uninterrupted reference run proves loss-curve
continuity: rewind-and-replay from real checkpoints reproduces the clean
curve bit for bit.

    PYTHONPATH=src python examples/fault_tolerant_training.py              # ~1 min
    PYTHONPATH=src python examples/fault_tolerant_training.py --substrate sim
    PYTHONPATH=src python examples/fault_tolerant_training.py --no-verify  # skip the reference run
"""
import argparse

from repro.substrate import build_substrate
from repro.substrate.driver import DriveConfig, KillSpec, run_protected


def build(mode: str, steps: int):
    if mode == "process":
        return build_substrate("process", n_ranks=2, n_spares=2, seed=0,
                               total_steps=steps, batch=4, seq=32)
    return build_substrate("sim", n_nodes=4, n_spares=4)


def drive(mode: str, steps: int, ckpt_every: int, kills=()):
    sub = build(mode, steps)
    try:
        return run_protected(
            sub, DriveConfig(total_steps=steps, ckpt_every=ckpt_every,
                             scenario=f"example_{mode}"), kills)
    finally:
        sub.close()


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--substrate", default="process",
                    choices=("process", "sim"))
    ap.add_argument("--steps", type=int, default=24)
    ap.add_argument("--ckpt-every", type=int, default=6)
    ap.add_argument("--no-verify", action="store_true",
                    help="skip the uninterrupted reference run")
    args = ap.parse_args()

    kills = (KillSpec(args.steps * 3 // 8, 1),
             KillSpec(args.steps * 5 // 7, 0, "network"))
    what = ("2 real JAX rank processes (SIGKILL faults)"
            if args.substrate == "process"
            else "4 modelled nodes (failed-node faults)")
    print(f"substrate: {args.substrate} — {what}")
    print(f"kills scripted at steps {[k.step for k in kills]}; "
          f"checkpoints every {args.ckpt_every} steps\n")

    rep = drive(args.substrate, args.steps, args.ckpt_every, kills)

    print(f"completed={rep['completed']} steps={rep['steps_done']} "
          f"lost_steps={rep['lost_steps']}")
    print(f"restarts: in-place={rep['restarts']['inplace']} "
          f"rescheduled={rep['restarts']['resched']} "
          f"evicted={rep['evicted_nodes']}")
    print(f"planner decisions: {rep['decisions']['by_decision']}")
    print(f"modeled downtime: {rep['modeled']['downtime_s']:.0f} s "
          f"({rep['modeled']['downtime_s']/60:.1f} min; paper: ~12 min/restart)")
    print(f"final loss: {rep['final_loss']}")
    print("\nFSM history:")
    for _t, s, r in rep["state_history"]:
        print(f"  {s:>16s}  {r[:60]}")

    if not args.no_verify:
        print("\nuninterrupted reference run (loss-continuity check) ...")
        clean = drive(args.substrate, args.steps, args.ckpt_every)
        same = clean["losses"] == rep["losses"]
        print(f"loss curves identical: {same} "
              f"(clean final loss: {clean['final_loss']})")
        if not same:
            raise SystemExit("continuity check FAILED")


if __name__ == "__main__":
    main()
