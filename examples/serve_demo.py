"""Batched serving demo: prefill + greedy decode with per-family caches
(KV for attention, latent for MLA, O(1) conv+SSM state for mamba2).

    PYTHONPATH=src python examples/serve_demo.py [arch ...]
"""
import sys
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.models import init_params
from repro.serve.engine import greedy_generate, serve_params_cast


def main():
    archs = sys.argv[1:] or ["llama3-8b", "mamba2-130m", "deepseek-v3-671b"]
    for arch in archs:
        cfg = get_config(arch).reduced()
        params = serve_params_cast(init_params(cfg, jax.random.key(0)), cfg)
        b, s, steps = 4, 32, 16
        key = jax.random.key(1)
        batch = {"tokens": jax.random.randint(key, (b, s), 0, cfg.vocab_size)}
        if cfg.family == "encdec":
            batch["enc_embeds"] = jax.random.normal(
                key, (b, cfg.encdec.enc_len, cfg.d_model), jnp.float32)
        if cfg.family == "vlm":
            batch["vision_embeds"] = jax.random.normal(
                key, (b, cfg.vlm.n_vision_tokens, cfg.d_model), jnp.float32)
        t0 = time.perf_counter()
        out = greedy_generate(params, cfg, batch, steps=steps)
        dt = time.perf_counter() - t0
        print(f"{arch:20s} batch={b} prompt={s} generated={steps} tokens/seq "
              f"in {dt:.2f}s -> {out[0, :8].tolist()} ...")


if __name__ == "__main__":
    main()
