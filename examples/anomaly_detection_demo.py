"""TEE demo: train the detector ensemble offline, register it (test-gated),
then detect + localise every fault category online.

    PYTHONPATH=src python examples/anomaly_detection_demo.py
"""
import tempfile

from repro.core.tee import (FAULT_CATEGORIES, ModelRegistry, OfflineTrainer,
                            TEEService, TraceGenerator)


def main():
    gen = TraceGenerator(n_ranks=8, seed=7)
    print("generating 13 normal traces; fitting LOF + NeighborProfile ...")
    normal = [gen.normal() for _ in range(13)]
    trainer = OfflineTrainer()
    models = trainer.fit(normal[:10])

    # evaluation gate + versioned registry
    labeled = normal[10:] + [gen.faulty(gen.sample_category()) for _ in range(11)]
    metrics = trainer.evaluate(models, labeled)
    print(f"offline eval: accuracy={metrics['accuracy']:.2f} "
          f"precision={metrics['precision']:.2f} recall={metrics['recall']:.2f}")
    reg = ModelRegistry(tempfile.mkdtemp(prefix="tee_registry_"))
    version = reg.register(models, metrics)
    print(f"registered model version v{version}\n")

    svc = TEEService(reg.load())
    print(f"{'category':12s} {'detected':9s} {'votes':38s} {'bad ranks (true)'}")
    for cat in FAULT_CATEGORIES:
        t = gen.faulty(cat, n_bad=1)
        v = svc.detect_task(t)
        votes = ",".join(k for k, on in v.votes.items() if on)
        print(f"{cat:12s} {str(v.anomalous):9s} {votes:38s} "
              f"{v.bad_ranks} ({t.bad_ranks})")


if __name__ == "__main__":
    main()
